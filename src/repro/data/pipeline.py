"""Synthetic, shard-aware training data pipeline with LSM-backed dedup.

Deterministic generation keyed by (seed, shard, step) — every data-parallel
host can regenerate its stream independently (restart-safe, no data service).
The dedup index is the paper's dictionary: each document's rolling hash is
bulk-looked-up; hits are replaced by fresh samples (one retry round), and the
batch of new hashes is bulk-inserted — a real streaming-ingest workload for
the LSM (the paper's motivating use case of dynamic ingest).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semantics as sem
from repro.core.lsm import LSMConfig, LSMState, lsm_init, lsm_update
from repro.core.queries import lsm_lookup


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    seed: int = 0
    dedup: bool = True
    dedup_levels: int = 16


class PipelineState(NamedTuple):
    dedup_index: LSMState
    duplicates_seen: jnp.ndarray  # int32[]


def _dedup_cfg(cfg: PipelineConfig) -> LSMConfig:
    return LSMConfig(batch_size=cfg.batch_per_shard, num_levels=cfg.dedup_levels)


def pipeline_init(cfg: PipelineConfig) -> PipelineState:
    return PipelineState(
        dedup_index=lsm_init(_dedup_cfg(cfg)),
        duplicates_seen=jnp.zeros((), jnp.int32),
    )


def _doc_hash(tokens):
    """Rolling polynomial hash -> 30-bit user key space."""
    k = jnp.asarray(31, jnp.uint32)
    h = jnp.zeros(tokens.shape[0], jnp.uint32)
    def body(h, col):
        return h * k + col.astype(jnp.uint32), None
    h, _ = jax.lax.scan(body, h, tokens.T.astype(jnp.uint32))
    return (h % jnp.uint32(sem.MAX_USER_KEY)).astype(jnp.int32)


def make_batch(cfg: PipelineConfig, shard: int, step: int):
    """Deterministic {tokens, labels} for (shard, step) — host-side numpy."""
    rng = np.random.default_rng((cfg.seed, shard, step))
    # Zipfian-ish token ids so duplicates actually occur across steps.
    toks = rng.zipf(1.3, size=(cfg.batch_per_shard, cfg.seq_len + 1)) % cfg.vocab_size
    toks = toks.astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


def dedup_batch(cfg: PipelineConfig, state: PipelineState, batch, shard: int, step: int):
    """Replace duplicate documents (by hash) with retry samples; update index.

    Returns (state, batch, num_dups). One retry round (documents that are
    duplicates twice in a row pass through — bounded work per step, standard
    for streaming dedup).
    """
    if not cfg.dedup:
        return state, batch, jnp.zeros((), jnp.int32)
    dcfg = _dedup_cfg(cfg)
    h = _doc_hash(batch["tokens"])
    found, _ = lsm_lookup(dcfg, state.dedup_index, h)
    # Retry samples for duplicate rows.
    retry = make_batch(cfg, shard, step + (1 << 20))
    mask = found[:, None]
    tokens = jnp.where(mask, retry["tokens"], batch["tokens"])
    labels = jnp.where(mask, retry["labels"], batch["labels"])
    h_new = jnp.where(found, _doc_hash(tokens), h)
    index = lsm_update(
        dcfg, state.dedup_index, sem.encode_insert(h_new),
        jnp.full_like(h_new, step % (1 << 30)),
    )
    n_dup = jnp.sum(found.astype(jnp.int32))
    return (
        PipelineState(index, state.duplicates_seen + n_dup),
        {"tokens": tokens, "labels": labels},
        n_dup,
    )
