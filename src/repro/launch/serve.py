"""Serving driver: batched prefill + decode with the LSM-backed page index.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 8 --gen-tokens 32

The full configs lower on the production mesh via launch/dryrun.py; this
driver executes reduced configs on the local devices with the same code path
(apply_prefill / apply_decode + PageTable admission/eviction), reporting
tokens/s and page-index statistics.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import model_zoo as zoo
from repro.serve.kvcache import (
    PageTableConfig, pt_allocate, pt_compact, pt_evict, pt_init, pt_seq_page_count,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving path: use examples/dictionary_serving.py patterns")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    decode = jax.jit(functools.partial(zoo.apply_decode, cfg))
    pt_cfg = PageTableConfig(num_pages=1024, update_batch=64, num_levels=10)
    table = pt_init(pt_cfg)
    rng = np.random.default_rng(0)

    total_tokens = 0
    t0 = time.perf_counter()
    n_waves = (args.requests + args.batch - 1) // args.batch
    for wave in range(n_waves):
        seq_ids = (np.arange(args.batch) + wave * args.batch).astype(np.int32)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
        if cfg.has_vision_stub:
            batch["patch_embeds"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        logits, caches = zoo.apply_prefill(
            cfg, params, batch, cache_pad_to=args.prompt_len + args.gen_tokens +
            (cfg.num_patches if cfg.has_vision_stub else 0))
        # admit prompt pages
        n_pages = max(1, args.prompt_len // args.page_size)
        b = pt_cfg.update_batch
        seqs = np.repeat(seq_ids, n_pages)
        pages = np.tile(np.arange(n_pages, dtype=np.int32), args.batch)
        table, _ = pt_allocate(
            pt_cfg, table,
            jnp.asarray(np.resize(seqs, b)), jnp.asarray(np.resize(pages, b)),
            jnp.asarray(np.arange(b) < len(seqs)))

        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        cache_len = jnp.asarray(
            args.prompt_len + (cfg.num_patches if cfg.has_vision_stub else 0), jnp.int32)
        for t in range(args.gen_tokens):
            logits, caches = decode(params, token, caches, cache_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cache_len = cache_len + 1
            total_tokens += args.batch
        counts, _ = pt_seq_page_count(pt_cfg, table, jnp.asarray(seq_ids), 256)
        print(f"wave {wave}: generated {args.gen_tokens} tok/seq; "
              f"pages/seq={np.asarray(counts).tolist()} free={int(table.free_count)}")
        # retire the wave
        table = pt_evict(
            pt_cfg, table,
            jnp.asarray(np.resize(seqs, b)), jnp.asarray(np.resize(pages, b)),
            jnp.asarray(np.arange(b) < len(seqs)))
    table = pt_compact(pt_cfg, table)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s); index compacted to r={int(table.lsm.r)}")


if __name__ == "__main__":
    main()
