"""Serving driver: batched prefill + decode with the LSM-backed page index.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 8 --gen-tokens 32

The full configs lower on the production mesh via launch/dryrun.py; this
driver executes reduced configs on the local devices with the same code path
(apply_prefill / apply_decode + page-table admission/eviction), reporting
tokens/s and page-index statistics.

The page table is driven through the continuous-batching `DictionaryServer`
(repro.serve.server): admissions, evictions, and per-sequence page counts are
submitted as ragged tenant ops and coalesce into shared device steps instead
of issuing one padded `pt_*` call each. The wave report includes the server's
step-coalescing stats (ops per device step, forced flushes, maintains)
alongside tokens/s — the serving-side evidence for the paper's batched-rate
claim. Pass --direct to fall back to the standalone `pt_*` path for
comparison.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import model_zoo as zoo
from repro.serve.kvcache import (
    PageTableConfig, ServerPageTable, pt_allocate, pt_compact, pt_evict,
    pt_init, pt_seq_page_count,
)
from repro.serve.server import DictionaryServer, ServerConfig


def _run_direct(args, cfg, params, decode, rng):
    """Standalone pt_* path: one padded device call per page-table op."""
    pt_cfg = PageTableConfig(num_pages=1024, update_batch=64, num_levels=10)
    table = pt_init(pt_cfg)
    total_tokens = 0
    t0 = time.perf_counter()
    n_waves = (args.requests + args.batch - 1) // args.batch
    for wave in range(n_waves):
        seq_ids, seqs, pages, token, caches, cache_len = _prefill_wave(
            args, cfg, params, rng, wave)
        b = pt_cfg.update_batch
        table, _ = pt_allocate(
            pt_cfg, table,
            jnp.asarray(np.resize(seqs, b)), jnp.asarray(np.resize(pages, b)),
            jnp.asarray(np.arange(b) < len(seqs)))
        for _ in range(args.gen_tokens):
            logits, caches = decode(params, token, caches, cache_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cache_len = cache_len + 1
            total_tokens += args.batch
        counts, _ = pt_seq_page_count(pt_cfg, table, jnp.asarray(seq_ids), 256)
        print(f"wave {wave}: generated {args.gen_tokens} tok/seq; "
              f"pages/seq={np.asarray(counts).tolist()} free={int(table.free_count)}")
        table = pt_evict(
            pt_cfg, table,
            jnp.asarray(np.resize(seqs, b)), jnp.asarray(np.resize(pages, b)),
            jnp.asarray(np.arange(b) < len(seqs)))
    table = pt_compact(pt_cfg, table)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s); index compacted to r={int(table.lsm.r)}")


def _run_server(args, cfg, params, decode, rng):
    """Server path: the page table is a tenant; ragged ops coalesce."""
    srv = DictionaryServer(ServerConfig(
        backend="lsm", batch_size=64, num_levels=10, maintenance_budget=128))
    pt = ServerPageTable(srv, num_pages=1024, num_seqs=max(256, args.requests))
    total_tokens = 0
    t0 = time.perf_counter()
    n_waves = (args.requests + args.batch - 1) // args.batch
    for wave in range(n_waves):
        seq_ids, seqs, pages, token, caches, cache_len = _prefill_wave(
            args, cfg, params, rng, wave)
        # Ragged admission: no resize-to-batch padding — the server buckets.
        _slots, _ = pt.allocate(seqs, pages)
        count_ticket = pt.seq_page_count(seq_ids)
        for _ in range(args.gen_tokens):
            logits, caches = decode(params, token, caches, cache_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cache_len = cache_len + 1
            total_tokens += args.batch
        counts, _ = count_ticket.result()   # steps the server loop
        print(f"wave {wave}: generated {args.gen_tokens} tok/seq; "
              f"pages/seq={np.asarray(counts).tolist()} free={pt.free_count}")
        pt.evict(seqs, pages)
    stats = srv.drain()          # queued evict tombstones land first...
    srv.cleanup()                # ...then the stop-the-world compaction
    jax.block_until_ready(srv.dictionary.state)
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s); index compacted to "
          f"r={int(srv.dictionary.state.r)}")
    print(f"server: {stats.submitted} ops in {stats.device_steps} device steps "
          f"({stats.ops_per_device_step:.2f} ops/step), "
          f"flushes={stats.flushes} maintains={stats.maintains} "
          f"lanes={stats.lanes_by_kind}")


def _prefill_wave(args, cfg, params, rng, wave):
    seq_ids = (np.arange(args.batch) + wave * args.batch).astype(np.int32)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.has_vision_stub:
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    logits, caches = zoo.apply_prefill(
        cfg, params, batch, cache_pad_to=args.prompt_len + args.gen_tokens +
        (cfg.num_patches if cfg.has_vision_stub else 0))
    n_pages = max(1, args.prompt_len // args.page_size)
    seqs = np.repeat(seq_ids, n_pages)
    pages = np.tile(np.arange(n_pages, dtype=np.int32), args.batch)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    cache_len = jnp.asarray(
        args.prompt_len + (cfg.num_patches if cfg.has_vision_stub else 0), jnp.int32)
    return seq_ids, seqs, pages, token, caches, cache_len


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--direct", action="store_true",
                    help="standalone pt_* path (no server coalescing)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving path: use examples/dictionary_serving.py patterns")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    decode = jax.jit(functools.partial(zoo.apply_decode, cfg))
    rng = np.random.default_rng(0)
    if args.direct:
        _run_direct(args, cfg, params, decode, rng)
    else:
        _run_server(args, cfg, params, decode, rng)


if __name__ == "__main__":
    main()
