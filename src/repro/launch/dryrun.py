"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: jit with explicit in/out shardings over the production mesh,
`.lower().compile()` must succeed, and the compiled artifact yields
memory_analysis / cost_analysis / the collective schedule for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

# The forced 512-device CPU platform MUST be configured before jax (or any
# repro module that imports jax) initializes the backend.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.shapes import get_shape, shapes_for
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo
from repro.optim.adam import AdamConfig, adam_init
from repro.train.options import PerfOptions
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

# --- TPU v5e hardware constants (roofline targets; container runs on CPU) ---
PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link (per-chip collective bandwidth unit)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str):
    """Per-chip bytes moved by collectives, parsed from partitioned HLO.

    Result shapes in post-SPMD HLO are per-device. Bytes-moved model (ring):
      all-reduce        2 * R * (g-1)/g
      all-gather        R * (g-1)/g          (R = gathered result)
      reduce-scatter    R * (g-1)            (R = scattered result)
      all-to-all        R * (g-1)/g
      collective-perm.  R
    """
    per_op = {}
    total = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        r = _shape_bytes(m.group("rtype"))
        tail = hlo_text[m.end() : m.end() + 2000]
        g = 2
        mg = _GROUPS_RE.search(tail)
        if mg:
            g = max(2, mg.group(1).count(",") + 1)
        else:
            mg = _GROUPS_IOTA_RE.search(tail)
            if mg:
                g = max(2, int(mg.group(2)))
        if op == "all-reduce":
            moved = 2 * r * (g - 1) / g
        elif op == "all-gather":
            moved = r * (g - 1) / g
        elif op == "reduce-scatter":
            moved = r * (g - 1)
        elif op == "all-to-all":
            moved = r * (g - 1) / g
        else:
            moved = float(r)
        key = op
        per_op.setdefault(key, {"count": 0, "bytes": 0.0})
        per_op[key]["count"] += 1
        per_op[key]["bytes"] += moved
        total += moved
    return total, per_op


def build_cell(arch: str, shape_name: str, mesh, moment_dtype=None, options=None):
    """Lower one (arch, shape) cell on `mesh`. Returns (jitted, args) specs."""
    options = options or PerfOptions()
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.name == "long_500k" and not cfg.supports_long_context():
        raise ValueError(f"{arch} skips long_500k (full attention; DESIGN.md §5)")

    params_spec = jax.eval_shape(lambda k: zoo.init_params(cfg, k), jax.random.PRNGKey(0))
    serve = options.serve_sharding and shape.kind in ("prefill", "decode")
    params_sh = shd.params_shardings(cfg, params_spec, mesh, serve=serve)

    specs = zoo.input_specs(cfg, shape)

    if shape.kind == "train":
        # bf16 Adam moments for the 671B config: fp32 moments exceed 16 GB/chip
        # on the single pod (see EXPERIMENTS.md §Dry-run).
        mdt = moment_dtype or (jnp.bfloat16 if arch == "deepseek-v3-671b" else jnp.float32)
        ocfg = AdamConfig(moment_dtype=mdt)
        opt_spec = jax.eval_shape(lambda p: adam_init(ocfg, p), params_spec)
        opt_sh = type(opt_spec)(
            m=shd.params_shardings(cfg, opt_spec.m, mesh),
            v=shd.params_shardings(cfg, opt_spec.v, mesh),
            step=shd.replicated(mesh),
        )
        batch_sh = shd.batch_shardings(specs["batch"], mesh)
        step_fn = make_train_step(cfg, ocfg, options)
        metrics_sh = {k: shd.replicated(mesh) for k in ("loss", "aux_loss", "grad_norm", "lr")}
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1),
        )
        args = (params_spec, opt_spec, specs["batch"])
    elif shape.kind == "prefill":
        batch_sh = shd.batch_shardings(specs["batch"], mesh)
        step_fn = make_prefill_step(cfg, options)
        caches_spec = jax.eval_shape(
            lambda p, b: step_fn(p, b)[1], params_spec, specs["batch"]
        )
        caches_sh = shd.cache_shardings(caches_spec, mesh)
        logits_sh = shd.batch_shardings(
            jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size), jnp.float32), mesh
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, batch_sh),
            out_shardings=(logits_sh, caches_sh),
        )
        args = (params_spec, specs["batch"])
    else:  # decode
        step_fn = make_decode_step(cfg, options)
        caches_sh = shd.cache_shardings(specs["caches"], mesh)
        token_sh = shd.batch_shardings(specs["token"], mesh)
        logits_sh = shd.batch_shardings(
            jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab_size), jnp.float32), mesh
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(params_sh, token_sh, caches_sh, shd.replicated(mesh)),
            out_shardings=(logits_sh, caches_sh, shd.replicated(mesh)),
            donate_argnums=(2,),
        )
        args = (params_spec, specs["token"], specs["caches"], jax.ShapeDtypeStruct((), jnp.int32))
    return cfg, shape, jitted, args


_WHILE_RE = re.compile(r"=\s*\([^)]*\)\s*while\(|=\s*[a-z0-9]+\[[0-9,]*\][^ ]*\s*while\(")


def _compile_and_measure(arch, shape_name, mesh, options):
    """One compile -> (cfg, shape, flops, bytes, coll_bytes, per_op, ma, has_loop)."""
    cfg, shape, jitted, args = build_cell(arch, shape_name, mesh, options=options)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll_bytes, coll_per_op = collective_stats(hlo)
    has_loop = bool(_WHILE_RE.search(hlo))
    return (cfg, shape, float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), coll_bytes, coll_per_op, ma,
            has_loop)


def _loop_trip_count(cfg):
    """Units of the (equal-sized) scan loops left after partial unroll."""
    from repro.models.transformer import FULL_UNROLL_THRESHOLD, decoder_plan

    counts = {c for c, _ in decoder_plan(cfg) if c > FULL_UNROLL_THRESHOLD}
    if cfg.is_encoder_decoder and cfg.num_encoder_layers > FULL_UNROLL_THRESHOLD:
        counts.add(cfg.num_encoder_layers)
    if not counts:
        return 0
    assert len(counts) == 1, f"unequal loop counts {counts}: extrapolation invalid"
    return counts.pop()


def run_cell(arch: str, shape_name: str, multi_pod: bool, options=None,
             exact: bool = True):
    """Compile one cell; return the roofline record.

    exact=True compiles twice (scan unroll u=1, u=2) and extrapolates the
    exact per-step FLOPs/bytes/collective bytes: XLA cost analysis counts a
    while body once, so f(u) = base + u * per_unit and
    true = f1 + (C - 1) * (f2 - f1) for a C-unit loop.
    """
    options = options or PerfOptions()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    # First compile at u=2 (a two-unit loop body is large enough that XLA does
    # not silently unroll the while loop itself, which would break the model).
    o2 = dataclasses.replace(options, scan_unroll=2)
    cfg, shape, flops2, bytes2, coll2, per_op2, ma, loop2 = _compile_and_measure(
        arch, shape_name, mesh, o2)
    C = _loop_trip_count(cfg)
    extrapolated = False
    if exact and C > 3 and loop2:
        o3 = dataclasses.replace(options, scan_unroll=3)
        _, _, flops3, bytes3, coll3, per_op3, _, loop3 = _compile_and_measure(
            arch, shape_name, mesh, o3)
        if loop3:
            # f(u) = base + u*p with the loop body counted once =>
            # exact = f2 + (C - 2) * (f3 - f2).
            k = C - 2
            flops = flops2 + k * (flops3 - flops2)
            bytes_accessed = bytes2 + k * (bytes3 - bytes2)
            coll_bytes = coll2 + k * (coll3 - coll2)
            coll_per_op = {}
            for op in set(per_op2) | set(per_op3):
                b2 = per_op2.get(op, {"bytes": 0.0, "count": 0})
                b3 = per_op3.get(op, {"bytes": 0.0, "count": 0})
                coll_per_op[op] = {
                    "count": b2["count"] + k * (b3["count"] - b2["count"]),
                    "bytes": b2["bytes"] + k * (b3["bytes"] - b2["bytes"]),
                }
            extrapolated = True
        else:
            # u=3 got fully unrolled by XLA: its counts are already exact.
            flops, bytes_accessed, coll_bytes, coll_per_op = flops3, bytes3, coll3, per_op3
    else:
        # No loop left (small model or XLA unrolled it): counts are exact.
        flops, bytes_accessed, coll_bytes, coll_per_op = flops2, bytes2, coll2, per_op2
    t_compile = time.time() - t0
    t_lower = 0.0
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_bytes / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = zoo.model_flops(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "options": {
            "sharded_loss": options.sharded_loss,
            "remat_policy": options.remat_policy,
            "zero3_gather": options.zero3_gather,
            "serve_sharding": options.serve_sharding,
        },
        "status": "ok",
        "exact_accounting": extrapolated or not loop2,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_chip": {
            "hlo_flops": flops,
            "hlo_bytes": bytes_accessed,
            "collective_bytes": coll_bytes,
            "collectives": coll_per_op,
            "memory_analysis": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
        },
        "roofline": {
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "dominant": dominant,
            "model_flops_global": mf,
            "model_flops_per_chip": mf / chips,
            "useful_flop_ratio": (mf / chips) / flops if flops else 0.0,
            "roofline_fraction": ((mf / chips) / PEAK_FLOPS)
            / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0
            else 0.0,
        },
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell (both meshes)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--sharded-loss", action="store_true")
    ap.add_argument("--zero3-gather", action="store_true")
    ap.add_argument("--serve-sharding", action="store_true")
    ap.add_argument("--attn-seq-shard", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="per-arch optimized recipe (EXPERIMENTS.md §Perf): "
                         "sharded_loss + zero3_gather + dots remat (+ "
                         "sequence-sharded attention when heads don't divide TP)")
    ap.add_argument("--remat", default="full", choices=("full", "dots", "none"))
    ap.add_argument("--no-exact", action="store_true",
                    help="single u=1 compile; loop bodies counted once (fast, "
                         "undercounts per-layer cost by the trip count)")
    ap.add_argument("--force", action="store_true", help="overwrite existing JSONs")
    args = ap.parse_args()
    options = PerfOptions(sharded_loss=args.sharded_loss, remat_policy=args.remat,
                          zero3_gather=args.zero3_gather,
                          serve_sharding=args.serve_sharding,
                          attn_seq_shard=args.attn_seq_shard)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in shapes_for(get_config(arch)):
                for mp in (False, True):
                    cells.append((arch, shape.name, mp))
    else:
        cells = [(args.arch, args.shape, args.multi_pod)]

    failures = 0
    for arch, shape_name, mp in cells:
        if args.opt:
            cfg_a = get_config(arch)
            seq_shard = bool(cfg_a.num_heads) and (
                cfg_a.num_heads % 16 != 0 or cfg_a.num_kv_heads % 16 != 0
            ) and not cfg_a.use_mla
            options = PerfOptions(
                sharded_loss=True, zero3_gather=True, remat_policy="dots",
                attn_seq_shard=seq_shard,
            )
        tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path) and not args.force:
            print(f"[skip] {tag} (exists)")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, mp, options=options,
                           exact=not args.no_exact)
            r = rec["roofline"]
            print(
                f"  ok: compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
                f"collective={r['collective_s']*1e3:.1f}ms dominant={r['dominant']} "
                f"roofline_frac={r['roofline_fraction']:.3f} "
                f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            rec = {
                "arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if mp else "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"  FAILED: {type(e).__name__}: {str(e)[:300]}", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
