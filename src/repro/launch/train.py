"""Production training driver: mesh discovery, sharded train step, LSM-dedup
data pipeline, fault-tolerant supervised loop, checkpoint/restart.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt

On real hardware the same entry point scales: the mesh is built from whatever
devices the runtime exposes (data x model best-fit), and restart under a
different device count is handled by the elastic checkpoint restore.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, dedup_batch, make_batch, pipeline_init
from repro.dist import sharding as shd
from repro.dist.fault_tolerance import StragglerMonitor, TrainSupervisor
from repro.models import model_zoo as zoo
from repro.optim.adam import AdamConfig, adam_init
from repro.train.steps import make_train_step


def best_fit_mesh():
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    from repro.compat import AxisType, make_mesh

    return make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(AxisType.Auto,) * 2,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a worker failure at this step (FT demo)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = best_fit_mesh()
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    ocfg = AdamConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(10, args.steps // 20))
    key = jax.random.PRNGKey(0)
    params = zoo.init_params(cfg, key)
    opt_state = adam_init(ocfg, params)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    print(f"[train] params: {n_params/1e6:.1f}M")

    params_sh = shd.params_shardings(cfg, params, mesh)
    opt_sh = type(opt_state)(
        m=shd.params_shardings(cfg, opt_state.m, mesh),
        v=shd.params_shardings(cfg, opt_state.v, mesh),
        step=shd.replicated(mesh),
    )
    params = jax.device_put(params, params_sh)
    opt_state = jax.device_put(opt_state, opt_sh)

    step_fn_raw = make_train_step(cfg, ocfg)
    metrics_sh = {k: shd.replicated(mesh) for k in ("loss", "aux_loss", "grad_norm", "lr")}

    pcfg = PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_per_shard=args.batch,
        dedup=not args.no_dedup,
    )
    pipe_state = pipeline_init(pcfg)

    sample = make_batch(pcfg, 0, 0)
    batch_sh = shd.batch_shardings(sample, mesh)
    jitted = jax.jit(
        step_fn_raw,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )

    ckpt = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
    sup = TrainSupervisor(ckpt, save_every=args.save_every,
                          monitor=StragglerMonitor())

    start_step = 0
    if args.resume and ckpt.latest_step() is not None:
        start_step = ckpt.latest_step()
        spec = {"params": params, "opt": opt_state}
        restored = ckpt.restore(start_step, spec,
                                shardings={"params": params_sh, "opt": opt_sh})
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start_step}")

    state = {"params": params, "opt": opt_state, "pipe": pipe_state}
    losses = []
    fail_at = {args.fail_at} if args.fail_at >= 0 else set()
    t_start = time.time()

    def step_fn(state, step):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("injected failure (FT demo)")
        batch = make_batch(pcfg, 0, step)
        pipe, batch, n_dup = dedup_batch(pcfg, state["pipe"], batch, 0, step)
        p, o, metrics = jitted(state["params"], state["opt"], batch)
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t_start
            tok_s = (step - start_step + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"  step {step:5d} loss {loss:.4f} gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} dups {int(n_dup)} tok/s {tok_s:,.0f}",
                  flush=True)
        return {"params": p, "opt": o, "pipe": pipe}

    sup_state, done = sup.run(state, step_fn, num_steps=args.steps, start_step=start_step)
    ckpt.wait()
    if sup.log:
        print("[train] supervisor log:")
        for line in sup.log:
            print("   ", line)
    print(f"[train] finished at step {done}; last losses: "
          f"{[round(l, 3) for l in losses[-5:]]}")
    if len(losses) >= 2 and losses[-1] < losses[0]:
        print("[train] loss decreased ✓")
    return losses


if __name__ == "__main__":
    main()
