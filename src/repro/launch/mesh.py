"""Production mesh construction (assignment: MULTI-POD DRY-RUN item 1)."""

from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; multi_pod adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for tests (requires forced host device count)."""
    if pod:
        return make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto,) * 2,
    )
