"""Production mesh construction (assignment: MULTI-POD DRY-RUN item 1).

Also owns the 1-D dictionary-shard mesh used by the `lsm_sharded` backend
(repro.api.backends): backends never call jax.make_mesh directly — mesh
construction and version shims stay in launch/ + repro.compat.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; multi_pod adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_shard_mesh(num_shards: Optional[int] = None, *, axis: str = "shard"):
    """1-D mesh over the first `num_shards` devices for the sharded dictionary.

    `num_shards=None` takes every visible device. On CPU the device pool can
    be widened with XLA_FLAGS=--xla_force_host_platform_device_count=N (set
    before jax initializes — tests/conftest.py does this for the suite).
    """
    devices = jax.devices()
    if num_shards is None:
        num_shards = len(devices)
    num_shards = int(num_shards)
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards > len(devices):
        raise ValueError(
            f"num_shards={num_shards} exceeds the {len(devices)} visible "
            "device(s); on CPU, force more host devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return make_mesh(
        (num_shards,), (axis,),
        axis_types=(AxisType.Auto,),
        devices=devices[:num_shards],
    )


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small host-device mesh for tests (requires forced host device count)."""
    if pod:
        return make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto,) * 2,
    )
