"""Serving layer: continuous-batching dictionary server + tenants.

`DictionaryServer` multiplexes many logical clients onto one device-resident
`Dictionary`, namespacing tenant keys into the shared 30-bit key space and
coalescing queued ops into per-op-kind device steps. `traffic` generates
serving-shaped multi-tenant op traces; `kvcache` is the KV-cache page table,
expressible either standalone (`pt_*`) or as a tenant of the server
(`ServerPageTable`).
"""

from repro.serve.server import (
    DictionaryServer,
    ServerConfig,
    ServerStats,
    Tenant,
    Ticket,
)
from repro.serve.traffic import TraceOp, TrafficGen, make_trace

__all__ = [
    "DictionaryServer",
    "ServerConfig",
    "ServerStats",
    "Tenant",
    "Ticket",
    "TraceOp",
    "TrafficGen",
    "make_trace",
]
