"""LSM-backed paged KV cache — the paper's dictionary as a serving feature.

vLLM-style paged attention needs a *mutable* mapping from logical pages
(sequence, page_index) — or prefix hashes for RadixAttention-style reuse — to
physical page slots. On a GPU that mapping is a host-side hash map; on TPU we
keep it device-resident in the GPU-LSM dictionary, exercising exactly the
paper's claim (fast batch inserts/deletes + lookups on-device):

  admission   = lsm_update with (page_key -> slot) inserts
  eviction    = lsm_update with tombstones (slots return to the free list)
  translation = bulk lsm_lookup (one per attention step)
  scans       = lsm_count/lsm_range over a sequence's key range (pages of one
                sequence are contiguous keys -> range queries enumerate them)

Keys pack (seq_id, page_idx) into the 30-bit user key space:
key = seq_id * MAX_PAGES_PER_SEQ + page_idx, so one sequence's pages occupy a
contiguous key range — COUNT(seq) and RANGE(seq) are the paper's ordered
queries doing real serving work (how many pages does this sequence hold /
enumerate them for defragmentation).

The page *payload* (the actual KV bytes) lives in a separate dense pool
[num_pages, ...]; this module manages only the index + free list, which is
what the dictionary is for.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.core.cleanup import lsm_cleanup
from repro.core.lsm import LSMConfig, LSMState, lsm_init, lsm_update
from repro.core.queries import lsm_count, lsm_lookup, lsm_range

MAX_PAGES_PER_SEQ = 1 << 12  # 4096 pages/sequence (x page_size tokens)


@dataclasses.dataclass(frozen=True)
class PageTableConfig:
    num_pages: int                 # physical slots in the KV pool
    update_batch: int = 256        # LSM batch size b (padded with placebos)
    num_levels: int = 12

    @property
    def lsm(self) -> LSMConfig:
        return LSMConfig(batch_size=self.update_batch, num_levels=self.num_levels)


class PageTableState(NamedTuple):
    lsm: LSMState
    free_count: jnp.ndarray        # int32[] — free slots remaining
    free_list: jnp.ndarray         # int32[num_pages] — stack of free slot ids


def page_key(seq_ids, page_idxs):
    return (jnp.asarray(seq_ids, jnp.int32) * MAX_PAGES_PER_SEQ
            + jnp.asarray(page_idxs, jnp.int32))


def pt_init(cfg: PageTableConfig) -> PageTableState:
    return PageTableState(
        lsm=lsm_init(cfg.lsm),
        free_count=jnp.asarray(cfg.num_pages, jnp.int32),
        free_list=jnp.arange(cfg.num_pages, dtype=jnp.int32)[::-1],
    )


def pt_allocate(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs, valid):
    """Allocate physical slots for up to `update_batch` logical pages.

    valid: bool mask (invalid lanes become placebo padding — partial batches
    per paper §4.1). Returns (state, slots) with slots[i] = -1 where invalid.
    """
    b = cfg.update_batch
    n_alloc = jnp.sum(valid.astype(jnp.int32))
    # Pop slots from the free-list stack.
    pos = state.free_count - 1 - jnp.cumsum(valid.astype(jnp.int32)) + valid.astype(jnp.int32)
    pos = jnp.where(valid, pos, 0)
    slots = jnp.where(valid, state.free_list[jnp.clip(pos, 0, cfg.num_pages - 1)], -1)
    keys = page_key(seq_ids, page_idxs)
    kv = jnp.where(valid, sem.encode_insert(keys), sem.PLACEBO_KV)
    vals = jnp.where(valid, slots, sem.EMPTY_VALUE)
    new_lsm = lsm_update(cfg.lsm, state.lsm, kv, vals)
    return PageTableState(new_lsm, state.free_count - n_alloc, state.free_list), slots


def pt_lookup(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs):
    """Translate logical pages -> physical slots. Returns (found, slots)."""
    return lsm_lookup(cfg.lsm, state.lsm, page_key(seq_ids, page_idxs))


def pt_evict(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs, valid):
    """Tombstone up to `update_batch` pages and push their slots back."""
    keys = page_key(seq_ids, page_idxs)
    found, slots = lsm_lookup(cfg.lsm, state.lsm, keys)
    valid = valid & found
    kv = jnp.where(valid, sem.encode_delete(keys), sem.PLACEBO_KV)
    vals = jnp.zeros_like(kv)
    new_lsm = lsm_update(cfg.lsm, state.lsm, kv, vals)
    # Push freed slots.
    n_freed = jnp.sum(valid.astype(jnp.int32))
    pos = state.free_count + jnp.cumsum(valid.astype(jnp.int32)) - 1
    pos = jnp.where(valid, pos, cfg.num_pages)  # dropped when invalid
    free_list = state.free_list.at[pos].set(jnp.where(valid, slots, -1), mode="drop")
    return PageTableState(new_lsm, state.free_count + n_freed, free_list)


def pt_seq_page_count(cfg: PageTableConfig, state: PageTableState, seq_ids,
                      max_candidates: int = 1 << 13):
    """COUNT over a sequence's contiguous key range — live pages per sequence."""
    k1 = page_key(seq_ids, jnp.zeros_like(seq_ids))
    k2 = page_key(seq_ids, jnp.full_like(seq_ids, MAX_PAGES_PER_SEQ - 1))
    return lsm_count(cfg.lsm, state.lsm, k1, k2, max_candidates)


def pt_seq_pages(cfg: PageTableConfig, state: PageTableState, seq_ids,
                 max_pages: int, max_candidates: int = 1 << 13):
    """RANGE over a sequence's key range — enumerate its pages in order
    (defragmentation / sequence migration)."""
    k1 = page_key(seq_ids, jnp.zeros_like(seq_ids))
    k2 = page_key(seq_ids, jnp.full_like(seq_ids, MAX_PAGES_PER_SEQ - 1))
    keys, slots, counts, ok = lsm_range(
        cfg.lsm, state.lsm, k1, k2, max_candidates, max_pages
    )
    page_idx = jnp.where(keys != sem.PLACEBO_KEY, keys % MAX_PAGES_PER_SEQ, -1)
    return page_idx, slots, counts, ok


def pt_compact(cfg: PageTableConfig, state: PageTableState) -> PageTableState:
    """Paper CLEANUP: purge tombstoned translations, shrink levels."""
    return PageTableState(lsm_cleanup(cfg.lsm, state.lsm), state.free_count, state.free_list)
