"""LSM-backed paged KV cache — the paper's dictionary as a serving feature.

vLLM-style paged attention needs a *mutable* mapping from logical pages
(sequence, page_index) — or prefix hashes for RadixAttention-style reuse — to
physical page slots. On a GPU that mapping is a host-side hash map; on TPU we
keep it device-resident behind the unified `Dictionary` facade (repro.api),
exercising exactly the paper's claim (fast batch inserts/deletes + lookups
on-device):

  admission   = index.update with (page_key -> slot) inserts
  eviction    = index.update with tombstones (slots return to the free list)
  translation = bulk index.lookup (one per attention step)
  scans       = index.count/range over a sequence's key range (pages of one
                sequence are contiguous keys -> range queries enumerate them)

Admissions/evictions arrive as ragged trickles (a few sequences grow a page
per decode step), and the facade's write buffer absorbs them: partial
batches stage into the index's "level −1" instead of round-tripping as
placebo-padded full batches, so each pt_allocate/pt_evict call no longer
burns one of the LSM's 2^L - 1 batch slots (staged pages are still visible
to every translation/scan). `PageTableConfig.flush_threshold` forwards the
facade's flush policy; `pt_flush` forces the buffer down explicitly (e.g.
before snapshotting the index).

Keys pack (seq_id, page_idx) into the 30-bit user key space:
key = seq_id * MAX_PAGES_PER_SEQ + page_idx, so one sequence's pages occupy a
contiguous key range — COUNT(seq) and RANGE(seq) are the paper's ordered
queries doing real serving work (how many pages does this sequence hold /
enumerate them for defragmentation).

The page *payload* (the actual KV bytes) lives in a separate dense pool
[num_pages, ...]; this module manages only the index + free list, which is
what the dictionary is for. The index is a pytree (the facade registers
`Dictionary` as a node), so PageTableState nests in jitted serving loops
unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.api import Dictionary, QueryPlan

MAX_PAGES_PER_SEQ = 1 << 12  # 4096 pages/sequence (x page_size tokens)


@dataclasses.dataclass(frozen=True)
class PageTableConfig:
    num_pages: int                 # physical slots in the KV pool
    update_batch: int = 256        # index batch size b (sub-batches stage)
    num_levels: int = 12
    backend: str = "lsm"           # any Dictionary backend with update support
    flush_threshold: int | None = None  # facade flush policy (None: overflow-only)
    # Budgeted incremental compaction between admission/eviction steps: every
    # pt_allocate / pt_evict piggybacks index.maintain(maintenance_budget)
    # behind a traced debt check, so tombstone/stale debt from evictions is
    # reclaimed in bounded slices instead of stop-the-world pt_compact spikes
    # on the decode path. None: no piggyback (compact explicitly).
    maintenance_budget: int | None = None

    def make_index(self) -> Dictionary:
        # validate=False: keys come from page_key(), never user input, and the
        # host-side domain check would force a device sync per translation.
        return Dictionary.create(
            self.backend, batch_size=self.update_batch, num_levels=self.num_levels,
            validate=False, flush_threshold=self.flush_threshold,
            maintenance_budget=self.maintenance_budget,
        )


class PageTableState(NamedTuple):
    index: Dictionary              # logical page -> physical slot
    free_count: jnp.ndarray        # int32[] — free slots remaining
    free_list: jnp.ndarray         # int32[num_pages] — stack of free slot ids

    @property
    def lsm(self):
        """Back-compat view: the raw core state behind the facade."""
        return self.index.state


def page_key(seq_ids, page_idxs):
    return (jnp.asarray(seq_ids, jnp.int32) * MAX_PAGES_PER_SEQ
            + jnp.asarray(page_idxs, jnp.int32))


def pt_init(cfg: PageTableConfig) -> PageTableState:
    return PageTableState(
        index=cfg.make_index(),
        free_count=jnp.asarray(cfg.num_pages, jnp.int32),
        free_list=jnp.arange(cfg.num_pages, dtype=jnp.int32)[::-1],
    )


def pt_allocate(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs, valid):
    """Allocate physical slots for up to `update_batch` logical pages.

    valid: bool mask (invalid lanes become placebo padding — partial batches
    per paper §4.1). Returns (state, slots) with slots[i] = -1 where invalid.
    """
    valid = jnp.asarray(valid, bool)
    n_alloc = jnp.sum(valid.astype(jnp.int32))
    # Pop slots from the free-list stack.
    pos = state.free_count - 1 - jnp.cumsum(valid.astype(jnp.int32)) + valid.astype(jnp.int32)
    pos = jnp.where(valid, pos, 0)
    slots = jnp.where(valid, state.free_list[jnp.clip(pos, 0, cfg.num_pages - 1)], -1)
    index = state.index.insert(page_key(seq_ids, page_idxs), slots, valid=valid)
    return PageTableState(index, state.free_count - n_alloc, state.free_list), slots


def pt_lookup(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs):
    """Translate logical pages -> physical slots. Returns (found, slots)."""
    del cfg
    return state.index.lookup(page_key(seq_ids, page_idxs))


def pt_evict(cfg: PageTableConfig, state: PageTableState, seq_ids, page_idxs, valid):
    """Tombstone up to `update_batch` pages and push their slots back."""
    keys = page_key(seq_ids, page_idxs)
    found, slots = state.index.lookup(keys)
    valid = jnp.asarray(valid, bool) & found
    index = state.index.delete(keys, valid=valid)
    # Push freed slots.
    n_freed = jnp.sum(valid.astype(jnp.int32))
    pos = state.free_count + jnp.cumsum(valid.astype(jnp.int32)) - 1
    pos = jnp.where(valid, pos, cfg.num_pages)  # dropped when invalid
    free_list = state.free_list.at[pos].set(jnp.where(valid, slots, -1), mode="drop")
    return PageTableState(index, state.free_count + n_freed, free_list)


def pt_seq_page_count(cfg: PageTableConfig, state: PageTableState, seq_ids,
                      max_candidates: int = 1 << 13):
    """COUNT over a sequence's contiguous key range — live pages per sequence."""
    del cfg
    k1 = page_key(seq_ids, jnp.zeros_like(seq_ids))
    k2 = page_key(seq_ids, jnp.full_like(seq_ids, MAX_PAGES_PER_SEQ - 1))
    return state.index.count(k1, k2, QueryPlan(max_candidates=max_candidates))


def pt_seq_pages(cfg: PageTableConfig, state: PageTableState, seq_ids,
                 max_pages: int, max_candidates: int = 1 << 13):
    """RANGE over a sequence's key range — enumerate its pages in order
    (defragmentation / sequence migration)."""
    del cfg
    k1 = page_key(seq_ids, jnp.zeros_like(seq_ids))
    k2 = page_key(seq_ids, jnp.full_like(seq_ids, MAX_PAGES_PER_SEQ - 1))
    keys, slots, counts, ok = state.index.range(
        k1, k2, QueryPlan(max_candidates=max_candidates, max_results=max_pages)
    )
    from repro.core import semantics as sem

    page_idx = jnp.where(keys != sem.PLACEBO_KEY, keys % MAX_PAGES_PER_SEQ, -1)
    return page_idx, slots, counts, ok


def pt_flush(cfg: PageTableConfig, state: PageTableState) -> PageTableState:
    """Force staged admissions/evictions out of the write buffer (e.g. before
    snapshotting the index). Translations never require this — staged pages
    are already visible to lookup/count/range."""
    del cfg
    return PageTableState(state.index.flush(), state.free_count, state.free_list)


def pt_compact(cfg: PageTableConfig, state: PageTableState) -> PageTableState:
    """Paper CLEANUP: purge tombstoned translations, shrink levels (folds any
    staged updates in — the cleanup-boundary flush)."""
    del cfg
    return PageTableState(state.index.cleanup(), state.free_count, state.free_list)


def pt_maintain(cfg: PageTableConfig, state: PageTableState,
                budget: int | None = None) -> PageTableState:
    """Explicit budgeted compaction of the index — the bounded-latency
    alternative to pt_compact for the serving loop. Touches at most `budget`
    resident translations (default: cfg.maintenance_budget; None degrades to
    a full cleanup). Translations stay exact at any debt level, so this can
    run between any two admission steps."""
    if budget is None:
        budget = cfg.maintenance_budget
    return PageTableState(
        state.index.maintain(budget), state.free_count, state.free_list
    )


# -- the page table as a server tenant ----------------------------------------


class _MappedTicket:
    """A server Ticket with a post-resolution transform (decode global keys
    back into (page_idx, slot) rows)."""

    __slots__ = ("_inner", "_fn")

    def __init__(self, inner, fn):
        self._inner = inner
        self._fn = fn

    def result(self):
        return self._fn(self._inner.result())


class ServerPageTable:
    """The KV page table re-expressed as one tenant of a `DictionaryServer`.

    The standalone `pt_*` path above owns a whole `Dictionary` and pads every
    ragged admission to `update_batch` itself. Under a server, the page table
    becomes *just another client*: it registers the tenant namespace
    ``seq_id * pages_per_seq + page_idx`` (the packing trick the server
    generalizes), submits ragged ops, and lets the scheduler coalesce them
    with every other tenant's traffic into shared device steps — the
    admission trickle of one model replica no longer costs a device call per
    decode step.

    Differences from the standalone path, forced by the move:

    * The free list lives host-side (a python stack). Slot choice is a
      host decision made at submit time; only the *mapping* is device state.
    * `allocate` returns the slots immediately (host free list) plus the
      update ticket; `evict` is a lookup ticket resolved through the server
      loop (coalescing with anything else queued) followed by a tombstone
      submit for the found keys.
    * Flush/compaction policy belongs to the server (its admission policy +
      `maintenance_budget`), not to this tenant.
    """

    def __init__(self, server, num_pages: int, name: str = "kvcache",
                 num_seqs: int = 256, pages_per_seq: int = MAX_PAGES_PER_SEQ):
        self.server = server
        self.name = name
        self.num_pages = int(num_pages)
        self.pages_per_seq = int(pages_per_seq)
        self.num_seqs = int(num_seqs)
        # May raise KeyDomainError — the shared key space is a real resource.
        self.tenant = server.register_tenant(
            name, key_space=self.num_seqs * self.pages_per_seq)
        self._free = list(range(self.num_pages - 1, -1, -1))

    # -- key packing (tenant-local) -------------------------------------------

    def _keys(self, seq_ids, page_idxs) -> np.ndarray:
        s = np.asarray(seq_ids, np.int64)
        p = np.asarray(page_idxs, np.int64)
        if (s >= self.num_seqs).any():
            raise ValueError(
                f"seq_id >= num_seqs={self.num_seqs}; widen the tenant")
        return s * self.pages_per_seq + p

    @property
    def free_count(self) -> int:
        return len(self._free)

    # -- ops ------------------------------------------------------------------

    def allocate(self, seq_ids, page_idxs):
        """Admit logical pages: pop physical slots host-side, queue the
        (page -> slot) inserts. Returns (slots, ticket) — the slots are
        usable immediately (writing KV bytes into the pool does not need the
        index), the ticket resolves once the insert's coalesced step runs."""
        keys = self._keys(seq_ids, page_idxs)
        n = len(keys)
        if n > len(self._free):
            raise RuntimeError(
                f"KV pool exhausted: need {n} pages, {len(self._free)} free")
        slots = np.asarray([self._free.pop() for _ in range(n)], np.int32)
        ticket = self.server.submit_update(self.name, keys, slots)
        return slots, ticket

    def lookup(self, seq_ids, page_idxs):
        """Translate logical pages -> slots; ticket resolves to
        (found, slots)."""
        return self.server.submit_lookup(self.name, self._keys(seq_ids, page_idxs))

    def evict(self, seq_ids, page_idxs) -> int:
        """Retire pages: resolve a translation through the server loop
        (coalescing with queued traffic), push found slots back onto the free
        list, tombstone the found keys. Returns the number of pages freed."""
        keys = self._keys(seq_ids, page_idxs)
        found, slots = self.server.submit_lookup(self.name, keys).result()
        freed = np.asarray(slots)[np.asarray(found)]
        self._free.extend(int(s) for s in freed)
        live = keys[np.asarray(found)]
        if len(live):
            self.server.submit_update(
                self.name, live, np.zeros(len(live), np.int32),
                is_delete=np.ones(len(live), bool))
        return len(freed)

    def seq_page_count(self, seq_ids):
        """COUNT over each sequence's key range; ticket -> (counts, ok)."""
        s = np.asarray(seq_ids, np.int64)
        return self.server.submit_count(
            self.name, self._keys(s, np.zeros_like(s)),
            self._keys(s, np.full_like(s, self.pages_per_seq - 1)))

    def seq_pages(self, seq_ids, max_pages: int):
        """RANGE over each sequence's key range; ticket ->
        (page_idx[n, max_pages] with -1 padding, slots, counts, ok)."""
        s = np.asarray(seq_ids, np.int64)
        inner = self.server.submit_range(
            self.name, self._keys(s, np.zeros_like(s)),
            self._keys(s, np.full_like(s, self.pages_per_seq - 1)),
            max_results=max_pages)

        def decode(res):
            from repro.core import semantics as sem

            keys, slots, counts, ok = res
            page_idx = np.where(
                keys != sem.PLACEBO_KEY, keys % self.pages_per_seq, -1)
            return page_idx, slots, counts, ok

        return _MappedTicket(inner, decode)
