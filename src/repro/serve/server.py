"""`DictionaryServer`: continuous batching for many tenants' op streams.

The paper's headline numbers are *batched* rates (hundreds of millions of
updates/lookups per second), but real clients do not arrive as 2^20-wide
batches — they arrive as thousands of logical streams issuing a handful of
ops each (a decode step admitting one KV page, a prefill admitting a burst,
an eviction sweep tombstoning a sequence). This module closes that gap the
way LSM-backed KV stores deploy: one server multiplexes every client's small
ops into large coalesced device steps against a single shared `Dictionary`.

Architecture (modeled on sglang-jax's ModelRunner / forward-batch split):

* **Tenant namespacing.** Each logical client registers a *tenant*: a named,
  contiguous extent of the shared 30-bit key space. Tenant-local keys in
  ``[0, key_space)`` pack to ``base + key`` — the generalization of
  `serve/kvcache.py`'s ``seq_id * MAX_PAGES_PER_SEQ + page_idx`` trick, which
  is now just one tenant whose local keys are themselves packed pairs.
  Registration raises `KeyDomainError` when the extent would overflow
  `MAX_USER_KEY`; deregistration tombstones the tenant's full key range and
  returns the extent to a free list. Because extents are disjoint, ops from
  different tenants *commute*: the scheduler may reorder across tenants while
  preserving only per-tenant program order.

* **Op queue + coalescing scheduler.** `submit_*` enqueues host-side (numpy)
  and returns a `Ticket`. `step()` drains the queue and schedules it into
  per-op-type device steps: repeatedly, each tenant's maximal head *run* of
  same-kind ops is a candidate; the kind with the most pending lanes executes
  next, coalescing every tenant's head run of that kind into ONE device call
  (one `update` / `lookup` / `count` / `range` on the shared handle).
  Homogeneous phases (every tenant decodes) collapse into a single device
  step; per-tenant program order is preserved exactly, so results are
  bit-identical to running each tenant call-at-a-time on its own dictionary
  (the differential test in tests/test_server.py pins this for lsm,
  sorted_array, and lsm_sharded). Coalesced batches are padded to bucketed
  lane counts (`lane_quantum` × powers of two) so the jit cache stays small.

* **Admission/flush policy.** Update lanes stage into the facade's write
  buffer ("level −1"); the server tracks a host-side occupancy model of
  `pending()` (exact — it owns every mutation) and forces a `flush()` when
  occupancy reaches ``flush_at_fraction * batch_size``, consulting
  `flush_cost_estimate()` for reporting. A `maintenance_budget` piggybacks
  budgeted compaction on every update/flush step (debt-gated, see DESIGN.md
  §11), and `drain()` runs an explicit idle-time `maintain()` so churn debt
  is repaid outside the latency path.

* **Donation-safe double buffering.** The server owns the `Dictionary`
  handle *linearly*: every mutating device step donates the old handle's
  buffers and the server immediately re-points at the returned generation,
  so host-side scheduling of step N+1 (queue drain, concat, pad) overlaps
  the device execution of step N — two generations are in flight, one being
  built on host, one being written on device, and XLA's donation machinery
  keeps them the same physical arena. Ownership rule: only the server may
  call mutators; `server.dictionary` is a *borrow* for reads/snapshots —
  mutating a borrowed handle would donate buffers the server still considers
  live (see docs/DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.api import Dictionary, KeyDomainError, QueryPlan
from repro.core import semantics as sem


# -- tenants ------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Tenant:
    """A registered namespace: tenant-local keys [0, key_space) live at
    [base, base + key_space) in the shared key domain."""

    name: str
    base: int
    key_space: int

    def pack(self, keys: np.ndarray) -> np.ndarray:
        return np.asarray(keys, np.int64) + self.base

    def unpack(self, global_keys: np.ndarray) -> np.ndarray:
        g = np.asarray(global_keys, np.int64)
        # Placebo padding rows (range results) stay placebo — they are not
        # keys of any tenant.
        return np.where(g == sem.PLACEBO_KEY, sem.PLACEBO_KEY, g - self.base)


# -- configuration / stats ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Static server + backing-dictionary configuration.

    backend/batch_size/num_levels/capacity/num_shards feed
    `Dictionary.create` (num_shards only for "lsm_sharded");
    `flush_threshold` / `maintenance_budget` are the facade's own policies
    and compose with the server's: `flush_at_fraction` is the server-level
    admission policy — force a flush when the (host-modeled) write-buffer
    occupancy reaches that fraction of batch_size. `lane_quantum` buckets
    coalesced update/lookup widths (quantum × power-of-two lanes) to bound
    jit-cache growth; `window_quantum` does the same for count/range groups
    and is deliberately tiny — window-query cost is linear in lanes (each
    lane runs the full candidate pipeline), so padding them to the update
    bucket would multiply real work, not amortize dispatch. `default_plan`
    overrides the auto-sized QueryPlan for count/range steps.
    """

    backend: str = "lsm"
    batch_size: int = 256
    num_levels: Optional[int] = None
    capacity: Optional[int] = None
    num_shards: Optional[int] = None
    flush_threshold: Optional[int] = None
    maintenance_budget: Optional[int] = None
    flush_at_fraction: float = 0.75
    lane_quantum: int = 64
    window_quantum: int = 2
    default_plan: Optional[QueryPlan] = None

    def make_dictionary(self) -> Dictionary:
        opts: Dict[str, object] = {"batch_size": self.batch_size}
        if self.num_levels is not None:
            opts["num_levels"] = self.num_levels
        if self.capacity is not None:
            opts["capacity"] = self.capacity
        if self.num_shards is not None:
            opts["num_shards"] = self.num_shards
        # validate=False: the server validates tenant-local domains itself at
        # submit time; re-checking packed keys per device step would add a
        # host-side scan on the hot path.
        return Dictionary.create(
            self.backend, validate=False,
            flush_threshold=self.flush_threshold,
            maintenance_budget=self.maintenance_budget, **opts,
        )


@dataclasses.dataclass
class ServerStats:
    """Coalescing/scheduling counters (host-side, exact)."""

    submitted: int = 0      # client ops accepted into the queue
    lanes: int = 0          # scalar lanes across those ops
    steps: int = 0          # step() drains that executed at least one group
    device_steps: int = 0   # coalesced device calls issued
    flushes: int = 0        # server-forced flush() calls (policy or explicit)
    maintains: int = 0      # explicit idle-time maintain() calls
    lanes_by_kind: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"update": 0, "lookup": 0, "count": 0, "range": 0}
    )

    @property
    def ops_per_device_step(self) -> float:
        return self.submitted / self.device_steps if self.device_steps else 0.0

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["ops_per_device_step"] = round(self.ops_per_device_step, 2)
        return d


# -- tickets ------------------------------------------------------------------


class Ticket:
    """Handle to one submitted op's eventual result.

    The result materializes when the server executes the op's coalesced
    group; `result()` triggers a `step()` if the op is still queued, then
    blocks only on the arrays this op needs (np.asarray forces the device
    sync — everything up to that group may still be executing
    asynchronously).
    """

    __slots__ = ("_server", "kind", "tenant", "_resolver", "_value", "_resolved")

    def __init__(self, server: "DictionaryServer", kind: str, tenant: str):
        self._server = server
        self.kind = kind
        self.tenant = tenant
        self._resolver: Optional[Callable[[], object]] = None
        self._value = None
        self._resolved = False

    @property
    def dispatched(self) -> bool:
        """Has the op's device step been issued (not necessarily finished)?"""
        return self._resolver is not None

    def result(self):
        if not self._resolved:
            if self._resolver is None:
                self._server.step()
            assert self._resolver is not None, "step() must dispatch every queued op"
            self._value = self._resolver()
            self._resolver = None
            self._resolved = True
        return self._value


@dataclasses.dataclass
class _QueuedOp:
    seq: int
    kind: str
    tenant: Tenant
    ticket: Ticket
    keys: Optional[np.ndarray] = None       # packed (global) keys
    values: Optional[np.ndarray] = None
    is_delete: Optional[np.ndarray] = None
    k1: Optional[np.ndarray] = None         # packed query bounds
    k2: Optional[np.ndarray] = None
    max_results: int = 0

    @property
    def lanes(self) -> int:
        if self.kind in ("update", "lookup"):
            return len(self.keys)
        return len(self.k1)


def _bucket(n: int, quantum: int) -> int:
    """Smallest quantum * 2^k >= n: bounds distinct compiled batch shapes to
    O(log total) per op kind."""
    m = quantum
    while m < n:
        m *= 2
    return m


def _next_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


# -- the server ---------------------------------------------------------------


class DictionaryServer:
    """Continuous-batching front end over one shared `Dictionary`.

    Typical lifecycle::

        srv = DictionaryServer(ServerConfig(backend="lsm", batch_size=256))
        a = srv.register_tenant("seq-a", key_space=4096)
        b = srv.register_tenant("seq-b", key_space=4096)
        t1 = srv.submit_update("seq-a", keys, values)
        t2 = srv.submit_lookup("seq-b", queries)
        srv.step()                  # coalesce + dispatch queued ops
        found, vals = t2.result()   # or call result() directly (auto-steps)
        srv.drain()                 # run everything, idle-maintain, block
    """

    def __init__(self, config: ServerConfig = ServerConfig(),
                 dictionary: Optional[Dictionary] = None):
        self.config = config
        self._d = dictionary if dictionary is not None else config.make_dictionary()
        self.stats = ServerStats()
        self._queue: List[_QueuedOp] = []
        self._seq = 0
        self._tenants: Dict[str, Tenant] = {}
        self._free_extents: List[Tuple[int, int]] = []  # (base, size), sorted
        self._next_base = 0
        # Host-side model of the write-buffer occupancy. Exact because the
        # server owns every mutation (asserted by tests against pending()).
        self._pending_model = 0

    # -- tenant registry ------------------------------------------------------

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenants)

    def tenant(self, name: str) -> Tenant:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(
                f"unknown tenant {name!r}; registered: {sorted(self._tenants)}"
            ) from None

    def register_tenant(self, name: str, key_space: int) -> Tenant:
        """Reserve a contiguous extent of `key_space` keys for `name`.

        Freed extents are reused first-fit (split on surplus); otherwise the
        extent is carved past the high-water mark. Raises `KeyDomainError`
        when the namespace would overflow the shared domain — the dictionary
        key space is a real resource the server arbitrates.
        """
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        key_space = int(key_space)
        if key_space < 1:
            raise ValueError(f"key_space must be >= 1, got {key_space}")
        base = None
        for i, (fb, fs) in enumerate(self._free_extents):
            if fs >= key_space:
                base = fb
                if fs > key_space:
                    self._free_extents[i] = (fb + key_space, fs - key_space)
                else:
                    del self._free_extents[i]
                break
        if base is None:
            base = self._next_base
            if base + key_space - 1 > sem.MAX_USER_KEY:
                raise KeyDomainError(
                    f"registering tenant {name!r} with key_space={key_space} "
                    f"at base={base} would overflow MAX_USER_KEY="
                    f"{sem.MAX_USER_KEY} (free: "
                    f"{sem.MAX_USER_KEY + 1 - base} keys + "
                    f"{sum(s for _, s in self._free_extents)} reclaimable)"
                )
            self._next_base = base + key_space
        t = Tenant(name=name, base=base, key_space=key_space)
        self._tenants[name] = t
        return t

    def deregister_tenant(self, name: str, chunk: int = 4096) -> int:
        """Tombstone the tenant's full key range and free its extent.

        Pending queued ops are drained first (their results must reflect the
        pre-deregistration state), then the extent is emptied with
        range-scan + tombstone rounds (`chunk` keys per round — bounded
        device batches even for huge namespaces). Returns the number of keys
        tombstoned. The freed extent becomes reusable by future
        registrations.
        """
        t = self.tenant(name)
        self.drain()
        lo = np.asarray([t.base], np.int64)
        hi = np.asarray([t.base + t.key_space - 1], np.int64)
        removed = 0
        limit = min(chunk, t.key_space)
        plan = QueryPlan(max_results=limit)
        while True:
            keys, _vals, counts, _ok = self._query(
                lambda d: d.range(lo, hi, plan)
            )
            n = int(np.asarray(counts)[0])
            # Only min(n, limit) rows are real — the rest is placebo padding
            # (counts report the FULL window population; rows are truncated
            # to the plan).
            take = min(n, limit)
            if take:
                live = np.asarray(keys)[0, :take]
                self._mutate(lambda d: d.delete(live))
                if self._d.buffered:
                    self._pending_model = self._model_stage(
                        self._pending_model, take)
                removed += take
            if n <= limit:
                break
        del self._tenants[name]
        self._free_extents.append((t.base, t.key_space))
        self._free_extents.sort()
        # Coalesce adjacent free extents (incl. the high-water tail) so
        # register/deregister churn cannot fragment the domain forever.
        merged: List[Tuple[int, int]] = []
        for fb, fs in self._free_extents:
            if merged and merged[-1][0] + merged[-1][1] == fb:
                merged[-1] = (merged[-1][0], merged[-1][1] + fs)
            else:
                merged.append((fb, fs))
        if merged and merged[-1][0] + merged[-1][1] == self._next_base:
            self._next_base = merged.pop()[0]
        self._free_extents = merged
        return removed

    # -- submission -----------------------------------------------------------

    def _check_local(self, t: Tenant, name: str, arr, upper: int) -> np.ndarray:
        a = np.asarray(arr)
        if a.ndim == 0:
            a = a[None]
        if a.ndim != 1:
            raise ValueError(f"{name} must be scalar or 1-D, got shape {a.shape}")
        if a.dtype.kind not in "iu":
            raise KeyDomainError(
                f"{name} must be integers, got dtype {a.dtype}"
            )
        a = a.astype(np.int64)
        bad = (a < 0) | (a >= upper)
        if bad.any():
            raise KeyDomainError(
                f"{name} outside tenant {t.name!r} key space [0, {upper}): "
                f"{a[bad][:5].tolist()}"
            )
        return a

    def _enqueue(self, op: _QueuedOp) -> Ticket:
        self._queue.append(op)
        self.stats.submitted += 1
        self.stats.lanes += op.lanes
        self.stats.lanes_by_kind[op.kind] += op.lanes
        return op.ticket

    def submit_update(self, tenant: str, keys, values=None, is_delete=None) -> Ticket:
        """Queue a ragged insert/delete batch of tenant-local keys. The
        ticket resolves to the number of lanes applied."""
        t = self.tenant(tenant)
        k = self._check_local(t, "update keys", keys, t.key_space)
        n = len(k)
        vals = (np.zeros(n, np.int32) if values is None
                else np.broadcast_to(np.asarray(values, np.int32), (n,)).copy())
        dels = (np.zeros(n, bool) if is_delete is None
                else np.broadcast_to(np.asarray(is_delete, bool), (n,)).copy())
        op = _QueuedOp(
            seq=self._next_seq(), kind="update", tenant=t,
            ticket=Ticket(self, "update", tenant),
            keys=t.pack(k), values=vals, is_delete=dels,
        )
        return self._enqueue(op)

    def submit_lookup(self, tenant: str, keys) -> Ticket:
        """Queue a batched lookup; resolves to (found[n], values[n])."""
        t = self.tenant(tenant)
        k = self._check_local(t, "lookup keys", keys, t.key_space)
        op = _QueuedOp(
            seq=self._next_seq(), kind="lookup", tenant=t,
            ticket=Ticket(self, "lookup", tenant), keys=t.pack(k),
        )
        return self._enqueue(op)

    def submit_count(self, tenant: str, k1, k2) -> Ticket:
        """Queue COUNT(k1, k2) windows (tenant-local, inclusive); resolves to
        (counts[n], ok[n])."""
        t = self.tenant(tenant)
        a = self._check_local(t, "count k1", k1, t.key_space)
        b = self._check_local(t, "count k2", k2, t.key_space)
        if a.shape != b.shape:
            raise ValueError(f"k1/k2 shapes differ: {a.shape}/{b.shape}")
        op = _QueuedOp(
            seq=self._next_seq(), kind="count", tenant=t,
            ticket=Ticket(self, "count", tenant),
            k1=t.pack(a), k2=t.pack(b),
        )
        return self._enqueue(op)

    def submit_range(self, tenant: str, k1, k2, max_results: int) -> Ticket:
        """Queue RANGE(k1, k2) windows; resolves to (keys[n, max_results],
        values, counts, ok) with keys unpacked back to tenant-local (placebo
        padding preserved)."""
        t = self.tenant(tenant)
        a = self._check_local(t, "range k1", k1, t.key_space)
        b = self._check_local(t, "range k2", k2, t.key_space)
        if a.shape != b.shape:
            raise ValueError(f"k1/k2 shapes differ: {a.shape}/{b.shape}")
        if max_results < 1:
            raise ValueError(f"max_results must be >= 1, got {max_results}")
        op = _QueuedOp(
            seq=self._next_seq(), kind="range", tenant=t,
            ticket=Ticket(self, "range", tenant),
            k1=t.pack(a), k2=t.pack(b), max_results=int(max_results),
        )
        return self._enqueue(op)

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # -- handle ownership -----------------------------------------------------

    @property
    def dictionary(self) -> Dictionary:
        """Borrow the current handle for reads/snapshots. Do NOT call
        mutators on it — they would donate buffers the server still owns
        (docs/DESIGN.md §12 ownership rules)."""
        return self._d

    def _mutate(self, fn) -> None:
        # Linear handle hand-off: fn consumes self._d (donation) and the
        # server re-points at the returned generation before the device step
        # necessarily finishes — this is the double-buffering overlap.
        self._d = fn(self._d)
        self.stats.device_steps += 1

    def _query(self, fn):
        out = fn(self._d)
        self.stats.device_steps += 1
        return out

    # -- occupancy model ------------------------------------------------------

    def _model_stage(self, pending: int, n_real: int) -> int:
        """Mirror lsm_stage overflow + the facade flush_threshold policy for
        `n_real` newly staged lanes (per-shard skew can only flush earlier,
        never retain more than the global model)."""
        pending += n_real
        b = self._d.batch_size
        while pending > b:
            pending -= b
        if (self.config.flush_threshold is not None
                and pending >= self.config.flush_threshold):
            pending = 0
        return pending

    def pending_estimate(self) -> int:
        """Host-side write-buffer occupancy model (no device sync). Exact
        for single-shard buffered backends (asserted in tests); sharded
        backends keep shard-local buffers that only flush on *local*
        overflow, so the device truth can exceed this model under even key
        spread — `occupancy()` reads the device truth when it matters."""
        return self._pending_model

    def occupancy(self):
        """Device-truth OccupancyStats of the backing dictionary (syncs)."""
        return self._d.occupancy()

    # -- scheduling -----------------------------------------------------------

    def step(self) -> int:
        """Drain the queue into coalesced per-op-type device steps.

        Scheduler: per-tenant program order is a hard constraint; across
        tenants, namespace disjointness makes ops commute. Each round takes
        every tenant's maximal head run of same-kind ops as a candidate
        group, executes the kind with the most pending lanes as one device
        call, and repeats. Returns the number of device steps issued.
        """
        drained, self._queue = self._queue, []
        if not drained:
            return 0
        issued0 = self.stats.device_steps
        per_tenant: "OrderedDict[str, List[_QueuedOp]]" = OrderedDict()
        for op in drained:
            per_tenant.setdefault(op.tenant.name, []).append(op)
        heads = {name: 0 for name in per_tenant}

        while True:
            # Candidate head runs, grouped by kind.
            by_kind: Dict[str, List[_QueuedOp]] = {}
            lanes: Dict[str, int] = {}
            first_seq: Dict[str, int] = {}
            for name, ops in per_tenant.items():
                i = heads[name]
                if i >= len(ops):
                    continue
                kind = ops[i].kind
                run = []
                while i < len(ops) and ops[i].kind == kind:
                    run.append(ops[i])
                    i += 1
                by_kind.setdefault(kind, []).extend(run)
                lanes[kind] = lanes.get(kind, 0) + sum(o.lanes for o in run)
                first_seq[kind] = min(first_seq.get(kind, run[0].seq), run[0].seq)
            if not by_kind:
                break
            kind = max(lanes, key=lambda k: (lanes[k], -first_seq[k]))
            group = sorted(by_kind[kind], key=lambda o: o.seq)
            for op in group:
                heads[op.tenant.name] += 1
            self._run_group(kind, group)

        self.stats.steps += 1
        return self.stats.device_steps - issued0

    def _run_group(self, kind: str, group: List[_QueuedOp]) -> None:
        {"update": self._run_update, "lookup": self._run_lookup,
         "count": self._run_count, "range": self._run_range}[kind](group)

    def _run_update(self, group: List[_QueuedOp]) -> None:
        n = sum(o.lanes for o in group)
        width = _bucket(n, self.config.lane_quantum)
        keys = np.zeros(width, np.int64)
        vals = np.zeros(width, np.int32)
        dels = np.zeros(width, bool)
        valid = np.zeros(width, bool)
        off = 0
        for op in group:
            m = op.lanes
            keys[off:off + m] = op.keys
            vals[off:off + m] = op.values
            dels[off:off + m] = op.is_delete
            valid[off:off + m] = True
            op.ticket._resolver = (lambda m=m: m)
            off += m
        self._mutate(lambda d: d.update(keys, vals, is_delete=dels, valid=valid))
        if not self._d.buffered:
            return
        self._pending_model = self._model_stage(self._pending_model, n)
        # Admission policy: force the deferred flush before the buffer
        # overflows mid-step — bounded-latency slot consumption instead of
        # surprise cascade pushes inside a later coalesced update.
        flush_at = max(1, int(self.config.flush_at_fraction * self._d.batch_size))
        if self._pending_model >= flush_at:
            self.flush()

    def _run_lookup(self, group: List[_QueuedOp]) -> None:
        n = sum(o.lanes for o in group)
        width = _bucket(n, self.config.lane_quantum)
        keys = np.zeros(width, np.int64)  # lane 0 pad: any in-domain key
        off = 0
        for op in group:
            keys[off:off + op.lanes] = op.keys
            off += op.lanes
        found, vals = self._query(lambda d: d.lookup(keys))
        off = 0
        for op in group:
            o, m = off, op.lanes

            def resolve(o=o, m=m):
                f = np.asarray(found[o:o + m])
                v = np.asarray(vals[o:o + m])
                return f, np.where(f, v, 0)

            op.ticket._resolver = resolve
            off += m

    def _query_windows(self, group: List[_QueuedOp]):
        n = sum(o.lanes for o in group)
        width = _bucket(n, self.config.window_quantum)
        # Pad with inverted windows (1, 0): zero candidates, zero results.
        k1 = np.full(width, 1, np.int64)
        k2 = np.zeros(width, np.int64)
        off = 0
        for op in group:
            k1[off:off + op.lanes] = op.k1
            k2[off:off + op.lanes] = op.k2
            off += op.lanes
        return k1, k2

    def _run_count(self, group: List[_QueuedOp]) -> None:
        k1, k2 = self._query_windows(group)
        plan = self.config.default_plan
        counts, ok = self._query(lambda d: d.count(k1, k2, plan))
        off = 0
        for op in group:
            o, m = off, op.lanes
            op.ticket._resolver = (
                lambda o=o, m=m: (np.asarray(counts[o:o + m]),
                                  np.asarray(ok[o:o + m]))
            )
            off += m

    def _run_range(self, group: List[_QueuedOp]) -> None:
        k1, k2 = self._query_windows(group)
        base_plan = self.config.default_plan or QueryPlan()
        rows = _next_pow2(max(o.max_results for o in group))
        plan = dataclasses.replace(base_plan, max_results=rows)
        keys, vals, counts, ok = self._query(lambda d: d.range(k1, k2, plan))
        off = 0
        for op in group:
            o, m, t, mr = off, op.lanes, op.tenant, op.max_results

            def resolve(o=o, m=m, t=t, mr=mr):
                rk = t.unpack(np.asarray(keys[o:o + m, :mr]))
                rv = np.asarray(vals[o:o + m, :mr])
                # counts stay the full window counts; overflow of the op's
                # own row budget surfaces as the truncation flag — exactly
                # the contract of a direct call with max_results=mr.
                rc = np.asarray(counts[o:o + m])
                rok = np.asarray(ok[o:o + m]) & (rc <= mr)
                return rk.astype(np.int64), rv, rc, rok

            op.ticket._resolver = resolve
            off += m

    # -- maintenance / lifecycle ---------------------------------------------

    def flush(self) -> None:
        """Force staged updates down into the main structure now."""
        self._mutate(lambda d: d.flush())
        self.stats.flushes += 1
        self._pending_model = 0

    def cleanup(self) -> None:
        """Full stop-the-world compaction of the shared handle (folds the
        write buffer in; `maintain()` is the bounded-latency alternative)."""
        self._mutate(lambda d: d.cleanup())
        self._pending_model = 0

    def maintain(self, budget: Optional[int] = None) -> None:
        """Explicit budgeted compaction on the shared handle (idle-time
        debt repayment; also piggybacked on update/flush when
        `maintenance_budget` is configured)."""
        if self._d.capabilities.supports_maintenance:
            self._mutate(lambda d: d.maintain(budget))
            self.stats.maintains += 1

    def drain(self) -> ServerStats:
        """Run every queued op, idle-maintain if configured, and block until
        the device is quiescent. Returns the stats snapshot."""
        import jax

        while self._queue:
            self.step()
        if (self.config.maintenance_budget is not None
                and self._d.capabilities.supports_maintenance):
            self.maintain(self.config.maintenance_budget)
        jax.block_until_ready(self._d.state)
        return self.stats
