"""Multi-tenant traffic generation + replay for the dictionary server.

Three serving-shaped traffic archetypes (the KV-cache workload's phases,
usable standalone or mixed):

* **decode-trickle** — every tenant admits one or two keys per event (a
  sequence growing a KV page per decode step) and occasionally looks a few
  recent keys back up. Thousands of tiny ragged updates: the write buffer's
  reason to exist, and the op stream that murders a call-at-a-time facade.
* **prefill-burst** — one tenant admits a contiguous run of keys in a single
  large update (a prompt's pages arriving at once), then counts its window.
* **eviction-storm** — one tenant tombstones a random swath of its live keys
  (sequence retirement / cache pressure) and range-scans the window to audit
  what survived.

Traces are plain per-tenant-local ops (`TraceOp`), so the same trace replays
through the coalescing server (`replay_server`) and through one direct
call-at-a-time `Dictionary` per tenant (`replay_direct`) — the differential
test asserts the results identical, the serve benchmark times the two paths
against each other. A pure-python oracle (`replay_oracle`) mirrors
tests/harness.py's arrival-order semantics per tenant.

Generators track per-tenant live-key state so deletes and lookups hit real
keys (plus deliberate misses); everything is driven by a seeded
`np.random.Generator` — same seed, same trace, no hypothesis required.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.api import Dictionary, QueryPlan
from repro.serve.server import DictionaryServer

# -- trace representation -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceOp:
    """One client op in tenant-local key space."""

    tenant: str
    kind: str                                # update | lookup | count | range
    keys: Optional[np.ndarray] = None        # update/lookup lanes
    values: Optional[np.ndarray] = None
    is_delete: Optional[np.ndarray] = None
    k1: Optional[np.ndarray] = None          # count/range windows (inclusive)
    k2: Optional[np.ndarray] = None
    max_results: int = 0

    @property
    def lanes(self) -> int:
        return len(self.keys) if self.keys is not None else len(self.k1)


MIXES = ("decode_trickle", "prefill_burst", "eviction_storm", "mixed")

# Event weights for the "mixed" archetype: mostly trickles with periodic
# bursts and storms — the shape of a serving steady state.
_MIXED_WEIGHTS = {"decode_trickle": 0.70, "prefill_burst": 0.18,
                  "eviction_storm": 0.12}


class TrafficGen:
    """Stateful generator: one event per call, per-tenant live-key tracking.

    `key_space` bounds every tenant's local domain; `window` bounds
    burst/storm/scan widths (and therefore range max_results — windows never
    exceed it, so range results are never truncated and replay paths agree
    bit-for-bit).
    """

    def __init__(self, tenants: Sequence[str], key_space: int, seed: int = 0,
                 window: int = 32):
        if window > key_space:
            raise ValueError(f"window={window} exceeds key_space={key_space}")
        self.tenants = list(tenants)
        self.key_space = int(key_space)
        self.window = int(window)
        self.rng = np.random.default_rng(seed)
        self._next_key = {t: 0 for t in self.tenants}   # decode growth cursor
        self._live: Dict[str, set] = {t: set() for t in self.tenants}

    # -- events (each returns a list of TraceOps) ----------------------------

    def decode_trickle(self, tenant: str) -> List[TraceOp]:
        """1-2 fresh keys admitted (wrapping cursor), sometimes a small
        lookback over recent + missing keys."""
        n = int(self.rng.integers(1, 3))
        start = self._next_key[tenant]
        keys = (start + np.arange(n)) % self.key_space
        self._next_key[tenant] = int((start + n) % self.key_space)
        vals = self.rng.integers(-1000, 1000, n).astype(np.int32)
        self._live[tenant].update(int(k) for k in keys)
        ops = [TraceOp(tenant, "update", keys=keys.astype(np.int64), values=vals,
                       is_delete=np.zeros(n, bool))]
        if self.rng.random() < 0.5:
            nq = int(self.rng.integers(1, 4))
            qs = (start - self.rng.integers(0, self.window, nq)) % self.key_space
            ops.append(TraceOp(tenant, "lookup", keys=qs.astype(np.int64)))
        return ops

    def prefill_burst(self, tenant: str) -> List[TraceOp]:
        """Contiguous window admitted in one update, then counted."""
        w = int(self.rng.integers(self.window // 2, self.window + 1))
        lo = int(self.rng.integers(0, self.key_space - w + 1))
        keys = np.arange(lo, lo + w, dtype=np.int64)
        vals = self.rng.integers(-1000, 1000, w).astype(np.int32)
        self._live[tenant].update(range(lo, lo + w))
        return [
            TraceOp(tenant, "update", keys=keys, values=vals,
                    is_delete=np.zeros(w, bool)),
            TraceOp(tenant, "count", k1=np.asarray([lo], np.int64),
                    k2=np.asarray([lo + w - 1], np.int64)),
        ]

    def eviction_storm(self, tenant: str) -> List[TraceOp]:
        """Tombstone a random swath of live keys, then audit the window."""
        live = self._live[tenant]
        lo = int(self.rng.integers(0, self.key_space - self.window + 1))
        hi = lo + self.window - 1
        in_window = sorted(k for k in live if lo <= k <= hi)
        if in_window:
            take = max(1, len(in_window) // 2)
            doomed = self.rng.choice(np.asarray(in_window, np.int64),
                                     take, replace=False)
        else:
            # Nothing live here: tombstone misses (legal, exercises
            # tombstones for absent keys).
            doomed = self.rng.integers(lo, hi + 1, 2).astype(np.int64)
        for k in doomed:
            live.discard(int(k))
        return [
            TraceOp(tenant, "update", keys=np.sort(doomed),
                    values=np.zeros(len(doomed), np.int32),
                    is_delete=np.ones(len(doomed), bool)),
            TraceOp(tenant, "range", k1=np.asarray([lo], np.int64),
                    k2=np.asarray([hi], np.int64), max_results=self.window),
        ]

    # -- trace assembly -------------------------------------------------------

    def make(self, mix: str, events: int) -> List[TraceOp]:
        """`events` generator events (each 1-2 ops). decode_trickle rotates
        tenants round-robin (every sequence decodes); burst/storm pick a
        random tenant per event; mixed draws the archetype per event."""
        if mix not in MIXES:
            raise ValueError(f"unknown mix {mix!r}; one of {MIXES}")
        names = list(_MIXED_WEIGHTS)
        probs = np.asarray([_MIXED_WEIGHTS[n] for n in names])
        ops: List[TraceOp] = []
        for i in range(events):
            kind = (mix if mix != "mixed"
                    else names[int(self.rng.choice(len(names), p=probs))])
            if kind == "decode_trickle":
                tenant = self.tenants[i % len(self.tenants)]
            else:
                tenant = self.tenants[int(self.rng.integers(len(self.tenants)))]
            ops.extend(getattr(self, kind)(tenant))
        return ops


def make_trace(mix: str, num_tenants: int, key_space: int, events: int,
               seed: int = 0, window: int = 32) -> Tuple[List[str], List[TraceOp]]:
    """Convenience wrapper: (tenant names, trace ops)."""
    tenants = [f"tenant{i:03d}" for i in range(num_tenants)]
    gen = TrafficGen(tenants, key_space=key_space, seed=seed, window=window)
    return tenants, gen.make(mix, events)


# -- replay paths -------------------------------------------------------------


def replay_server(server: DictionaryServer, trace: Sequence[TraceOp],
                  step_every: int = 64) -> List[object]:
    """Submit the whole trace through the coalescing server, stepping every
    `step_every` submissions (the continuous-batching window), and resolve
    every ticket. Returns per-op results aligned with the trace."""
    tickets = []
    for i, op in enumerate(trace):
        if op.kind == "update":
            t = server.submit_update(op.tenant, op.keys, op.values, op.is_delete)
        elif op.kind == "lookup":
            t = server.submit_lookup(op.tenant, op.keys)
        elif op.kind == "count":
            t = server.submit_count(op.tenant, op.k1, op.k2)
        else:
            t = server.submit_range(op.tenant, op.k1, op.k2, op.max_results)
        tickets.append(t)
        if (i + 1) % step_every == 0:
            server.step()
    server.drain()
    return [t.result() for t in tickets]


def replay_direct(make_dict, tenants: Sequence[str], trace: Sequence[TraceOp],
                  plan: Optional[QueryPlan] = None) -> List[object]:
    """The adoption-gap baseline: one private `Dictionary` per tenant
    (`make_dict()` builds each), every op its own facade call, results
    materialized immediately. Returns per-op results aligned with the
    trace — the format matches `replay_server` element-wise."""
    dicts: Dict[str, Dictionary] = {t: make_dict() for t in tenants}
    results: List[object] = []
    for op in trace:
        d = dicts[op.tenant]
        if op.kind == "update":
            dicts[op.tenant] = d.update(op.keys, op.values, is_delete=op.is_delete)
            results.append(len(op.keys))
        elif op.kind == "lookup":
            found, vals = d.lookup(op.keys)
            f, v = np.asarray(found), np.asarray(vals)
            results.append((f, np.where(f, v, 0)))
        elif op.kind == "count":
            counts, ok = d.count(op.k1, op.k2, plan)
            results.append((np.asarray(counts), np.asarray(ok)))
        else:
            p = dataclasses.replace(plan or QueryPlan(), max_results=op.max_results)
            keys, vals, counts, ok = d.range(op.k1, op.k2, p)
            results.append((np.asarray(keys).astype(np.int64), np.asarray(vals),
                            np.asarray(counts), np.asarray(ok)))
    import jax

    for d in dicts.values():
        jax.block_until_ready(d.state)
    return results


def replay_oracle(trace: Sequence[TraceOp]) -> Dict[str, dict]:
    """Per-tenant python-dict oracle with strict arrival-order semantics
    (tests/harness.py's recency rule, namespaced). Queries are not replayed —
    the final maps are the ground truth for end-state checks."""
    oracles: Dict[str, dict] = {}
    for op in trace:
        if op.kind != "update":
            continue
        o = oracles.setdefault(op.tenant, {})
        for k, v, dl in zip(op.keys, op.values, op.is_delete):
            if bool(dl):
                o.pop(int(k), None)
            else:
                o[int(k)] = int(v)
    return oracles
