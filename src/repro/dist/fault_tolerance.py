"""Fault-tolerant training: straggler detection + checkpoint/restart loop.

`TrainSupervisor` wraps a step function with save-every-k checkpointing and
restart-from-latest recovery: a step that raises is logged, the state is
restored from the newest checkpoint, and the steps since it are replayed —
exactly-once *effect* via idempotent replay, the standard large-job recovery
model. `StragglerMonitor` is the per-step EMA watchdog that flags steps whose
wall time blows past `threshold x` the running mean (slow host / degraded
interconnect detection).
"""

from __future__ import annotations

import jax
import numpy as np


class StragglerMonitor:
    """EMA-based step-time watchdog.

    observe(t) returns True (and counts the step) iff t exceeds
    `threshold * ema`. Flagged steps do not update the EMA — one straggler
    must not drag the baseline up and mask the next one.
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 3.0):
        self.alpha = float(alpha)
        self.threshold = float(threshold)
        self.ema: float | None = None
        self.flagged_steps = 0

    def observe(self, step_time: float) -> bool:
        t = float(step_time)
        if self.ema is None:
            self.ema = t
            return False
        if t > self.threshold * self.ema:
            self.flagged_steps += 1
            return True
        self.ema = self.alpha * t + (1.0 - self.alpha) * self.ema
        return False


class TrainSupervisor:
    """Supervised training loop: run `num_steps` steps with checkpoint/restart.

    step_fn(state, step) -> state may raise (node failure, preemption); the
    supervisor restores the latest checkpoint and replays from there, up to
    `max_restarts` times. Steps are replayed against the restored state, so a
    deterministic step_fn yields the same final state as a failure-free run.
    """

    def __init__(self, checkpoint_manager, save_every: int = 1, max_restarts: int = 3,
                 monitor: StragglerMonitor | None = None):
        self.cm = checkpoint_manager
        self.save_every = int(save_every)
        self.max_restarts = int(max_restarts)
        self.monitor = monitor
        self.restarts = 0
        self.log: list[str] = []

    def _spec(self, state):
        return jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(np.shape(l), np.asarray(l).dtype), state
        )

    def run(self, state, step_fn, num_steps: int, start_step: int = 0):
        """Returns (final_state, completed_steps)."""
        import time

        initial = jax.tree_util.tree_map(lambda l: l, state)  # restart-from-zero copy
        step = start_step
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                state = step_fn(state, step)
                if self.monitor is not None and self.monitor.observe(
                    time.perf_counter() - t0
                ):
                    self.log.append(f"STRAGGLER at step {step}")
                step += 1
                if step % self.save_every == 0:
                    self.cm.save(step, state)
            except Exception as e:  # noqa: BLE001 — any step failure is recoverable
                self.log.append(f"FAILURE at step {step}: {e!r}")
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    self.log.append("restart budget exhausted; re-raising")
                    raise
                latest = self.cm.latest_step()
                if latest is None:
                    state, step = initial, start_step
                    self.log.append("RESTART from initial state (no checkpoint)")
                else:
                    state = self.cm.restore(latest, self._spec(state))
                    step = latest
                    self.log.append(f"RESTART from checkpoint step {latest}")
        if hasattr(self.cm, "wait"):
            self.cm.wait()  # drain any in-flight async save before reporting done
        return state, step
