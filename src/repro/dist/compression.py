"""Gradient compression for the data-parallel all-reduce: int8 quantization
with error feedback (1-bit-Adam-family technique).

Each float leaf is quantized to int8 against a per-leaf absmax scale *after*
adding the residual carried over from the previous step; the quantization
residual becomes the next step's carry. Error feedback turns the biased
per-step rounding into an unbiased long-run average, so repeated compression
of a constant gradient converges to the exact mean.

The cross-device combine averages the *dequantized* tensors (scales differ
per device, so the int8 payloads cannot be summed directly; a production
variant would all-gather the 4-byte scales and psum the int8 payload — the
numerics below are identical).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _is_float(leaf) -> bool:
    return hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)


def init_error_state(tree):
    """Zero residual for every float leaf (int leaves carry no error)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros_like(l) if _is_float(l) else jnp.zeros((), l.dtype), tree
    )


def compressed_tree_psum(tree, axis_name: str, error_state):
    """Inside shard_map: mean-reduce `tree` over `axis_name` via int8 + EF.

    Returns (mean_tree, new_error_state). Must be called under a mapped axis
    named `axis_name`.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        if not _is_float(g):
            return jax.lax.psum(g, axis_name) // n, e
        t = g + e
        scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(g.dtype) * scale
        mean = jax.lax.psum(deq, axis_name) / n
        return mean, t - deq

    flat_g, treedef = jax.tree_util.tree_flatten(tree)
    flat_e = treedef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean_tree = jax.tree_util.tree_unflatten(treedef, [m for m, _ in out])
    new_err = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return mean_tree, new_err
