"""Sharding hints and sharding-spec construction for the training stack.

Two layers:

* `hint(x, *spec)` / `regather_params_tp(params)` — *in-graph* layout
  constraints used inside model code. They consult the ambient mesh at trace
  time and degrade to identity when there is none (CPU tests, single-device
  runs), so model code never branches on the environment. Axis names absent
  from the ambient mesh and axes that do not divide the dimension are dropped
  rather than erroring — a hint is advice to the partitioner, not a contract.

* `params_shardings` / `batch_shardings` / `replicated` — *out-of-graph*
  NamedSharding trees handed to jit's in/out_shardings by the launch layer.
  The parameter rule is tensor-parallel-greedy: shard the last mesh-divisible
  dimension of every >=2D leaf over the "model" axis, replicate the rest.
  Batches shard their leading (batch) dimension over "data" (and "pod" when
  present).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _ambient_mesh():
    """The mesh of the enclosing `with mesh:` scope, or None."""
    try:  # modern jax: explicit-sharding aware accessor
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except AttributeError:
        pass
    try:  # classic thread-resources env (jax <= 0.4.x and still-supported)
        mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except AttributeError:
        pass
    return None


def _clean_entry(mesh, entry, dim: int):
    """Keep only mesh-resident axis names whose product divides `dim`."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    names = tuple(n for n in names if n in mesh.axis_names)
    if not names:
        return None
    size = math.prod(mesh.shape[n] for n in names)
    if size <= 1 or dim % size != 0:
        return None
    return names[0] if len(names) == 1 else names


def hint(x, *spec):
    """Soft sharding constraint: `hint(x, ("pod", "data"), "model", None)`.

    One spec entry per array dimension (missing trailing entries mean
    replicated). Off-mesh this is the identity, which is what makes the
    PerfOptions equivalence tests meaningful on CPU.
    """
    mesh = _ambient_mesh()
    if mesh is None or not hasattr(x, "ndim"):
        return x
    entries = list(spec[: x.ndim]) + [None] * (x.ndim - len(spec))
    cleaned = tuple(_clean_entry(mesh, e, x.shape[i]) for i, e in enumerate(entries))
    if all(e is None for e in cleaned):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*cleaned)))


def regather_params_tp(params):
    """ZeRO-3-style regather: constrain a (scanned-unit) param tree to fully
    replicated so the partitioner materializes each unit's weights just before
    use and frees them after. Identity off-mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return params
    repl = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda l: jax.lax.with_sharding_constraint(l, repl) if hasattr(l, "ndim") else l,
        params,
    )


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def stacked_shardings(tree, mesh, axis: str):
    """NamedSharding tree splitting each leaf's leading (stacking) axis.

    The distributed dictionary keeps per-shard states stacked on a leading
    axis of size num_shards (core/distributed.py); every leaf of the state
    pytree gets P(axis, None, ...) so shard s's slice lives on device s.
    """
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, P(axis, *([None] * (l.ndim - 1)))), tree
    )


def _model_spec(shape, mesh) -> P:
    """Shard the last model-divisible dim of a >=2D leaf over "model"."""
    if "model" not in mesh.axis_names or len(shape) < 2:
        return P()
    m = mesh.shape["model"]
    for d in range(len(shape) - 1, 0, -1):  # never the leading (scan/stack) axis
        if m > 1 and shape[d] % m == 0:
            return P(*([None] * d + ["model"] + [None] * (len(shape) - d - 1)))
    return P()


def params_shardings(cfg, params, mesh, serve: bool = False):
    """NamedSharding tree for a parameter tree (or ShapeDtypeStruct specs).

    `serve=True` uses the same layout — decode-time layouts only diverge once
    weight-stationary serving optimizations land; keeping one code path keeps
    checkpoints portable between the two.
    """
    del cfg, serve
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, _model_spec(getattr(l, "shape", ()), mesh)), params
    )


def _batch_spec(shape, mesh) -> P:
    names = [n for n in ("pod", "data") if n in mesh.axis_names and mesh.shape[n] > 1]
    if not shape or not names:
        return P()
    size = math.prod(mesh.shape[n] for n in names)
    if shape[0] % size != 0:
        return P()
    entry = names[0] if len(names) == 1 else tuple(names)
    return P(*([entry] + [None] * (len(shape) - 1)))


def batch_shardings(batch, mesh):
    """Data-parallel sharding for a batch tree: leading dim over data axes."""
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, _batch_spec(getattr(l, "shape", ()), mesh)), batch
    )
