"""Distributed-training utilities: sharding specs, fault tolerance, gradient
compression. Everything degrades to a no-op / pure-local path off-mesh so the
same model code runs unchanged on a laptop CPU and a multi-pod mesh."""
