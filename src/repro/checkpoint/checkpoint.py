"""Fault-tolerant checkpointing: atomic, async, elastic (mesh-agnostic).

Format: one directory per step — `step_<n>/` with one .npy per pytree leaf
(path-encoded filenames) + a JSON manifest. Writes go to `step_<n>.tmp/` and
are renamed into place (atomic on POSIX), so a host failure mid-write can
never corrupt the latest checkpoint. Restore never needs the saving mesh:
leaves are plain host arrays and are re-placed under whatever shardings the
*current* mesh prescribes — this is what makes elastic re-scaling (restart on
a different pod count) a restore-time no-op.

Async mode hands the device->host copy + file write to a background thread; the
training loop only blocks if a previous save is still in flight (single
in-flight save, bounded memory).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")

# numpy can't serialize ml_dtypes (bf16/fp8) natively: store a same-width
# integer view and round-trip the true dtype through the manifest.
_VIEW_FOR = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}


def _to_storable(arr: np.ndarray):
    view = _VIEW_FOR.get(arr.dtype)
    return (arr.view(view), str(arr.dtype)) if view else (arr, str(arr.dtype))


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.kind in "ui" and dtype_name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return arr


def _leaf_filename(path_str: str) -> str:
    return _SAFE.sub("_", path_str).strip("_") + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> None:
        """Snapshot `tree` at `step` (blocking unless async_save)."""
        # Device->host copy happens on the caller thread (arrays may be
        # donated/overwritten by the next step); file IO can be deferred.
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        host = [(jax.tree_util.keystr(p), np.asarray(l)) for p, l in flat]
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for path_str, arr in host_leaves:
            fname = _leaf_filename(path_str)
            storable, dtype_name = _to_storable(arr)
            np.save(os.path.join(tmp, fname), storable)
            manifest["leaves"].append({"path": path_str, "file": fname,
                                       "shape": list(arr.shape), "dtype": dtype_name})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Rebuild `target_tree`'s structure from disk.

        shardings: optional matching tree of NamedSharding — leaves are placed
        directly under the current mesh (elastic restore).
        """
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        sh_flat = None
        if shardings is not None:
            sh_flat = jax.tree_util.tree_flatten(shardings)[0]
        leaves = []
        for i, (p, spec) in enumerate(flat):
            path_str = jax.tree_util.keystr(p)
            entry = by_path.get(path_str)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {path_str}")
            arr = _from_storable(np.load(os.path.join(d, entry["file"])), entry["dtype"])
            if tuple(arr.shape) != tuple(spec.shape):
                raise ValueError(f"{path_str}: shape {arr.shape} != {tuple(spec.shape)}")
            if sh_flat is not None:
                leaves.append(jax.device_put(arr, sh_flat[i]))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
