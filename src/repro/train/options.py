"""Performance knobs for the §Perf hillclimb — every option preserves
semantics; each is OFF in the paper-faithful baseline and toggled one at a
time in EXPERIMENTS.md §Perf with before/after roofline terms.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PerfOptions:
    # Vocab-sharded cross entropy: keep logits sharded over the model axis
    # through the loss (one-hot einsum + sharded logsumexp) instead of letting
    # the partitioner all-gather [B,S,V] fp32 logits for take_along_axis.
    sharded_loss: bool = False
    # ZeRO-3 weight regather: params live FSDP-sharded, and each scan body
    # re-constrains its layer slice to a TP-only layout — one weight
    # all-gather per layer instead of partial-matmul + activation all-reduce
    # (the partitioner's default resolution of contraction-dim sharding).
    zero3_gather: bool = False
    # Inference layout for serve steps: no FSDP, experts EP over data x model,
    # dense weights TP-only (dist/sharding.py param_pspec(serve=True)).
    serve_sharding: bool = False
    # Sequence-sharded attention activations (see layers.set_attn_seq_shard).
    attn_seq_shard: bool = False
    # Rematerialization: "full" (per-unit checkpoint, baseline), "dots"
    # (save matmul outputs — recompute only elementwise), "none".
    remat_policy: str = "full"
    # Unroll layer scans (int): 0 = keep loops, -1 = full unroll, u > 0 =
    # u units per loop iteration (groups with <= 8 units always fully
    # unroll). Only used by the dry-run: XLA cost analysis counts a
    # while-loop body ONCE, so exact HLO flop/byte/collective accounting uses
    # two partial-unroll compiles (u=1, u=2) and extrapolates
    # true = f1 + (C-1) * (f2 - f1). Numerically identical math.
    scan_unroll: int = 0


BASELINE = PerfOptions()


def resolve(options: "PerfOptions | None") -> PerfOptions:
    return options if options is not None else BASELINE
