"""Training / serving step functions (the units the dry-run lowers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_zoo as zoo
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.train.options import PerfOptions, resolve as resolve_options


from repro.dist.sharding import hint as _maybe_constrain


def softmax_xent(logits, labels, sharded: bool = False):
    """Token-mean cross entropy, fp32 accumulation, bf16 logits in.

    sharded=True keeps the vocab dimension sharded through the loss: the
    label logit is extracted with a fused iota-compare-reduce (partial over
    the local vocab shard + tiny all-reduce) and logsumexp reduces the
    sharded axis in place — the partitioner never all-gathers [B,S,V] fp32
    logits, which is the single largest collective in the naive train step
    for large-vocab models (EXPERIMENTS.md §Perf/H1).
    """
    lf = logits.astype(jnp.float32)
    if sharded:
        lf = _maybe_constrain(lf, ("pod", "data"), None, "model")
        m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
        return jnp.mean(lse - gold)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_train_step(cfg: ModelConfig, ocfg: AdamConfig, options: PerfOptions | None = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opts = resolve_options(options)

    def train_step(params, opt_state: AdamState, batch):
        def loss_fn(p):
            logits, aux = zoo.apply_train(cfg, p, batch, options=opts)
            loss = softmax_xent(logits, batch["labels"], sharded=opts.sharded_loss)
            return loss + 0.01 * aux, (loss, aux)

        (_, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adam_update(ocfg, params, grads, opt_state)
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, options: PerfOptions | None = None):
    opts = resolve_options(options)

    def prefill_step(params, batch):
        return zoo.apply_prefill(cfg, params, batch, options=opts)

    return prefill_step


def make_decode_step(cfg: ModelConfig, options: PerfOptions | None = None):
    opts = resolve_options(options)

    def decode_step(params, token, caches, cache_len):
        logits, new_caches = zoo.apply_decode(cfg, params, token, caches, cache_len,
                                              options=opts)
        return logits, new_caches, cache_len + 1

    return decode_step


def init_train_state(cfg: ModelConfig, ocfg: AdamConfig, key):
    params = zoo.init_params(cfg, key)
    return params, adam_init(ocfg, params)
