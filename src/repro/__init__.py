"""repro: TPU LSM dictionary runtime + multi-pod JAX LM framework."""

__version__ = "0.1.0"
