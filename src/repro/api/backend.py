"""Backend protocol + registry for the unified `Dictionary` facade.

A backend is a *static* (frozen, hashable) description of one dictionary
implementation: it owns the functional core's config and adapts the core's
free functions to a uniform method surface over an opaque pytree state. The
facade keys its compiled-executable cache on the backend instance, so
hashability is load-bearing, not a style choice.

Capability flags make the paper's Table 1 machine-checkable: an op a backend
cannot answer (cuckoo COUNT/RANGE, cuckoo incremental insert) raises
`CapabilityError` up front with the list of backends that can — never a
silently missing feature.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, ClassVar, Dict, NamedTuple, Tuple, Type

from repro.api.plan import QueryPlan

# Backend state is an arbitrary pytree (LSMState, SAState, CuckooTable, ...).
BackendState = Any


class OccupancyStats(NamedTuple):
    """Cheap structural introspection for serving schedulers (int32 scalars).

    Unlike `size()` these never run query machinery — they read counters the
    state already carries, so a server can poll them between coalesced steps
    without paying a multi-run scan.
    """

    pending: Any   # staged write-buffer elements awaiting a flush
    resident: Any  # elements resident in the main structure (stale included)
    debt: Any      # estimated reclaimable stale elements (maintenance target)


class CapabilityError(NotImplementedError):
    """An operation the chosen backend cannot support (paper Table 1)."""


class KeyDomainError(ValueError):
    """Keys outside [0, MAX_USER_KEY] — they would alias the placebo key or
    flip sign under the `key << 1` status-bit encoding and silently corrupt
    ordering (core/semantics.py)."""


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can do. Flags mirror the paper's Table 1 columns."""

    supports_updates: bool          # incremental batch insert
    supports_deletes: bool          # incremental batch delete (tombstones)
    supports_ordered_queries: bool  # COUNT / RANGE
    supports_cleanup: bool          # stale-element purge
    supports_bulk_build: bool = True
    supports_maintenance: bool = False  # budgeted incremental compaction


class Backend(abc.ABC):
    """Adapter from one functional core to the facade's uniform surface.

    Implementations are frozen dataclasses; `name` and `caps` are class
    attributes. States flow through unchanged — the facade never inspects
    them beyond treating them as pytrees.
    """

    name: ClassVar[str]
    caps: ClassVar[Capabilities]

    # -- static geometry ----------------------------------------------------

    @property
    @abc.abstractmethod
    def batch_size(self) -> int:
        """Width b of one encoded update batch (facade pads/splits to this)."""

    @property
    @abc.abstractmethod
    def capacity(self) -> int:
        """Maximum resident encoded elements (incl. stale); for partitioned
        backends, the *guaranteed* global budget (worst-case ownership skew)."""

    @property
    def max_query_candidates(self) -> int:
        """Largest number of resident elements one [k1, k2] query window can
        overlap: capacity plus any write-buffer slots. QueryPlan auto-sizing
        clamps to this (clamping to bare capacity would leave a full
        structure's count/range permanently inexact once the buffer holds
        residents). Buffered backends override."""
        return self.capacity

    @property
    def has_write_buffer(self) -> bool:
        """Does this backend stage updates in a write buffer (flush/pending
        are meaningful) rather than applying them immediately? Serving
        schedulers gate their occupancy/flush policies on this."""
        return False

    @property
    def num_shards(self) -> int:
        """Device partitions behind this backend (1 = single-device).

        Partitioned backends (lsm_sharded) override this; the facade's
        pad/split update path is shard-agnostic either way — each b-wide
        chunk reaches `update_encoded` whole, and the backend routes lanes
        to owners itself.
        """
        return 1

    # -- construction -------------------------------------------------------

    @classmethod
    @abc.abstractmethod
    def from_options(cls, **options) -> "Backend":
        """Build from `Dictionary.create(...)` keyword options."""

    @abc.abstractmethod
    def init(self) -> BackendState:
        """Empty state."""

    # -- ops (jit-traceable; called under the facade's compiled cache) ------

    def bulk_build(self, keys, values) -> BackendState:
        raise CapabilityError(self._no("bulk_build"))

    def update_encoded(self, state: BackendState, key_vars, values) -> BackendState:
        """Apply one b-wide encoded batch (key-variables + values)."""
        raise CapabilityError(self._no("update"))

    def stage_encoded(self, state: BackendState, key_vars, values, count) -> BackendState:
        """Stage one b-wide encoded sub-batch: the `count` real lanes are
        front-compacted in arrival order, the rest placebo.

        Contract: the later lane is the newer write — a later insert beats an
        earlier same-call tombstone (the write-buffer recency rule,
        docs/DESIGN.md §5) — and `count` bounds the occupancy a buffered
        backend may consume (placebo lanes never occupy buffer slots).
        Backends without a staging buffer apply immediately with an
        equivalent recency-sorted merge (see SortedArrayBackend)."""
        raise CapabilityError(self._no("update"))

    def flush_state(self, state: BackendState, min_pending: int = 1) -> BackendState:
        """Push staged (write-buffer) updates into the main structure when at
        least `min_pending` are buffered. Default: no buffer, nothing to do."""
        del min_pending
        return state

    def pending_count(self, state: BackendState):
        """Staged-but-unflushed element count (int32 scalar; 0 if unbuffered)."""
        del state
        import jax.numpy as jnp

        return jnp.zeros((), jnp.int32)

    def occupancy(self, state: BackendState) -> OccupancyStats:
        """Structural occupancy counters (see OccupancyStats). The default
        derives everything from pending_count — backends with richer state
        (resident batches, debt trackers) override with cheaper/fuller reads."""
        import jax.numpy as jnp

        zero = jnp.zeros((), jnp.int32)
        return OccupancyStats(
            pending=self.pending_count(state), resident=zero, debt=zero
        )

    def flush_cost(self, state: BackendState):
        """Estimated elements a `flush_state` would touch *now* (int32 scalar;
        0 when nothing is staged). Serving schedulers weigh this against
        buffer occupancy when choosing a flush point; backends without a
        buffer flush for free."""
        del state
        import jax.numpy as jnp

        return jnp.zeros((), jnp.int32)

    @abc.abstractmethod
    def lookup(self, state: BackendState, keys) -> Tuple[Any, Any]:
        """Batched LOOKUP -> (found, values)."""

    def count(self, state: BackendState, k1, k2, plan: QueryPlan):
        raise CapabilityError(self._no("count"))

    def range(self, state: BackendState, k1, k2, plan: QueryPlan):
        raise CapabilityError(self._no("range"))

    def cleanup(self, state: BackendState) -> BackendState:
        raise CapabilityError(self._no("cleanup"))

    def maintain_state(
        self,
        state: BackendState,
        budget: int | None,
        *,
        only_if_debt: bool = False,
    ) -> BackendState:
        """Budgeted incremental compaction: reclaim stale elements touching at
        most `budget` residents (STATIC int; None = full cleanup). Backends
        that never accumulate stale elements return the state unchanged, so
        maintenance is always safe to schedule."""
        del budget, only_if_debt
        return state

    @abc.abstractmethod
    def size(self, state: BackendState):
        """Live (visible) element count as an int32 scalar."""

    @abc.abstractmethod
    def overflowed(self, state: BackendState):
        """bool scalar — has any update exceeded static capacity?"""

    # -- diagnostics ---------------------------------------------------------

    def _no(self, op: str) -> str:
        alts = [n for n, c in _REGISTRY.items() if n != self.name and _op_supported(c, op)]
        return (
            f"backend {self.name!r} does not support {op!r}"
            + (f"; use backend={alts!r}" if alts else "")
        )


def _op_supported(cls: Type[Backend], op: str) -> bool:
    caps = cls.caps
    return {
        "update": caps.supports_updates,
        "insert": caps.supports_updates,
        "delete": caps.supports_deletes,
        "count": caps.supports_ordered_queries,
        "range": caps.supports_ordered_queries,
        "cleanup": caps.supports_cleanup,
        "maintain": caps.supports_maintenance,
        "bulk_build": caps.supports_bulk_build,
        "lookup": True,
    }.get(op, False)


_REGISTRY: Dict[str, Type[Backend]] = {}


def register_backend(cls: Type[Backend]) -> Type[Backend]:
    """Class decorator: make a Backend reachable via Dictionary.create(name)."""
    if not getattr(cls, "name", None):
        raise ValueError(f"backend class {cls.__name__} must define a name")
    _REGISTRY[cls.name] = cls
    return cls


def get_backend_class(name: str) -> Type[Backend]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
