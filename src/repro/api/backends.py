"""Built-in backends: the paper's three dictionary data structures adapted to
the `Backend` protocol (LSM §3-4, sorted array §5.1, cuckoo hash §5.1), plus
the range-partitioned multi-device LSM ("lsm_sharded").

Each adapter is a frozen dataclass wrapping the functional core's static
config; all array work stays in `repro.core.*` — these classes only translate
the uniform facade surface into the core's free-function calls.

Mesh/axis requirements (lsm_sharded)
------------------------------------
The sharded backend runs one full local LSM per device over a contiguous key
range (core/distributed.py). It needs a 1-D jax mesh whose named axis (default
``"shard"``) enumerates the shard devices:

  * ``Dictionary.create("lsm_sharded", num_shards=4)`` builds the mesh itself
    via `repro.launch.mesh.make_shard_mesh` over the first 4 visible devices
    (`num_shards=None` → every visible device);
  * or pass an existing mesh: ``create("lsm_sharded", mesh=m, axis="shard")``
    — the axis must exist in ``m.axis_names`` and its size becomes the shard
    count. Extra mesh axes are tolerated (the state is replicated over them).

The mesh is static backend identity: it rides in the frozen dataclass (jax
meshes are hashable), keys the facade's compiled-executable cache, and crosses
jit boundaries in the treedef. `batch_size` is the *global* update width —
every shard consumes the all-gathered batch with non-owned lanes turned into
placebos, so the per-shard batch-of-b invariant (and the unchanged local
binary-counter cascade) holds. `capacity` is likewise the guaranteed global
budget: each global batch ticks every shard's resident-batch counter, so the
per-shard arena must be able to hold every batch until a cleanup.

On CPU, spoof a multi-device pool with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (before jax
initializes) — this is how the parity tests in tests/test_backend_parity.py
exercise 1/2/4 shards.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.api.backend import (
    Backend,
    Capabilities,
    OccupancyStats,
    register_backend,
)
from repro.api.plan import QueryPlan
from repro.core import cleanup as lsm_cleanup_mod
from repro.core import cuckoo as ck
from repro.core import distributed as dist
from repro.core import queries
from repro.core import sorted_array as sa
from repro.core.lsm import (
    LSMConfig,
    all_runs,
    lsm_bulk_build,
    lsm_debt,
    lsm_flush,
    lsm_flush_cost,
    lsm_init,
    lsm_stage,
    lsm_update,
)


def _levels_for(capacity: int, batch_size: int) -> int:
    """Smallest L with b * (2^L - 1) >= capacity."""
    batches = -(-capacity // batch_size)
    return max(1, math.ceil(math.log2(batches + 1)))


@register_backend
@dataclasses.dataclass(frozen=True)
class LSMBackend(Backend):
    """The paper's GPU LSM: amortized O(b log r) updates, ordered queries."""

    name = "lsm"
    caps = Capabilities(
        supports_updates=True,
        supports_deletes=True,
        supports_ordered_queries=True,
        supports_cleanup=True,
        supports_maintenance=True,
    )

    cfg: LSMConfig

    @classmethod
    def from_options(cls, *, capacity=None, batch_size=None, num_levels=None, **extra):
        if extra:
            raise TypeError(f"unknown options for backend 'lsm': {sorted(extra)}")
        b = int(batch_size) if batch_size is not None else 1024
        if num_levels is None:
            num_levels = _levels_for(int(capacity) if capacity else b * 1023, b)
        return cls(LSMConfig(batch_size=b, num_levels=int(num_levels)))

    @property
    def batch_size(self) -> int:
        return self.cfg.batch_size

    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    @property
    def max_query_candidates(self) -> int:
        # Levels plus the b write-buffer slots a query window can overlap.
        return self.cfg.capacity + self.cfg.batch_size

    @property
    def has_write_buffer(self) -> bool:
        return True

    def init(self):
        return lsm_init(self.cfg)

    def bulk_build(self, keys, values):
        return lsm_bulk_build(self.cfg, keys, values)

    def update_encoded(self, state, key_vars, values):
        return lsm_update(self.cfg, state, key_vars, values)

    def stage_encoded(self, state, key_vars, values, count):
        return lsm_stage(self.cfg, state, key_vars, values, count)

    def flush_state(self, state, min_pending: int = 1):
        return lsm_flush(self.cfg, state, min_pending)

    def pending_count(self, state):
        return state.buf_n

    def occupancy(self, state):
        return OccupancyStats(
            pending=state.buf_n,
            resident=state.r * self.cfg.batch_size,
            debt=lsm_debt(self.cfg, state),
        )

    def flush_cost(self, state):
        return lsm_flush_cost(self.cfg, state)

    def lookup(self, state, keys):
        return queries.lookup_runs(all_runs(self.cfg, state), keys)

    def count(self, state, k1, k2, plan: QueryPlan):
        return queries.count_runs(all_runs(self.cfg, state), k1, k2, plan.max_candidates)

    def range(self, state, k1, k2, plan: QueryPlan):
        return queries.range_runs(
            all_runs(self.cfg, state), k1, k2, plan.max_candidates, plan.max_results
        )

    def cleanup(self, state):
        return lsm_cleanup_mod.lsm_cleanup(self.cfg, state)

    def maintain_state(self, state, budget, *, only_if_debt=False):
        return lsm_cleanup_mod.lsm_maintain(
            self.cfg, state, budget, only_if_debt=only_if_debt
        )

    def size(self, state):
        return queries.valid_count_runs(all_runs(self.cfg, state))

    def overflowed(self, state):
        return state.overflowed


@register_backend
@dataclasses.dataclass(frozen=True)
class ShardedLSMBackend(Backend):
    """Range-partitioned LSM over a device mesh: one local LSM per shard,
    routed by key ownership (core/distributed.py). Full capability row — the
    distributed structure loses nothing vs the single-device LSM; ordered
    queries stay shard-local + a psum/assembly combine.

    See the module docstring for mesh/axis requirements.
    """

    name = "lsm_sharded"
    caps = Capabilities(
        supports_updates=True,
        supports_deletes=True,
        supports_ordered_queries=True,
        supports_cleanup=True,
        supports_maintenance=True,
    )

    cfg: dist.DistLSMConfig
    mesh: object  # jax.sharding.Mesh — hashable, static backend identity

    @classmethod
    def from_options(
        cls, *, capacity=None, batch_size=None, num_levels=None,
        num_shards=None, mesh=None, axis="shard", **extra,
    ):
        if extra:
            raise TypeError(f"unknown options for backend 'lsm_sharded': {sorted(extra)}")
        if mesh is None:
            from repro.launch.mesh import make_shard_mesh

            mesh = make_shard_mesh(num_shards, axis=axis)
        if axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has no axis {axis!r} (axes: {tuple(mesh.axis_names)})"
            )
        shards = int(mesh.shape[axis])
        if num_shards is not None and int(num_shards) != shards:
            raise ValueError(
                f"num_shards={num_shards} disagrees with mesh axis {axis!r} "
                f"of size {shards}"
            )
        b = int(batch_size) if batch_size is not None else 1024
        if num_levels is None:
            num_levels = _levels_for(int(capacity) if capacity else b * 1023, b)
        return cls(
            dist.DistLSMConfig(
                local=LSMConfig(batch_size=b, num_levels=int(num_levels)),
                num_shards=shards,
                axis=axis,
            ),
            mesh,
        )

    @property
    def batch_size(self) -> int:
        return self.cfg.local.batch_size

    @property
    def capacity(self) -> int:
        # Per-shard arena size == guaranteed global budget: every global
        # batch ticks every shard's resident-batch counter (placebo lanes
        # included), so one shard could end up holding all of it.
        return self.cfg.local.capacity

    @property
    def max_query_candidates(self) -> int:
        # max_candidates is applied per shard (queries clip to shard windows),
        # so the bound is the per-shard arena plus its local write buffer.
        return self.cfg.local.capacity + self.cfg.local.batch_size

    @property
    def num_shards(self) -> int:
        return self.cfg.num_shards

    @property
    def has_write_buffer(self) -> bool:
        return True

    def init(self):
        return dist.dist_lsm_init(self.cfg, self.mesh)

    def bulk_build(self, keys, values):
        return dist.dist_bulk_build(self.cfg, self.mesh, keys, values)

    def update_encoded(self, state, key_vars, values):
        return dist.dist_update(self.cfg, self.mesh, state, key_vars, values)

    def stage_encoded(self, state, key_vars, values, count):
        return dist.dist_stage(self.cfg, self.mesh, state, key_vars, values, count)

    def flush_state(self, state, min_pending: int = 1):
        return dist.dist_flush(self.cfg, self.mesh, state, min_pending)

    def pending_count(self, state):
        return dist.dist_pending(self.cfg, self.mesh, state)

    def occupancy(self, state):
        pending, resident, debt = dist.dist_occupancy(self.cfg, self.mesh, state)
        return OccupancyStats(pending=pending, resident=resident, debt=debt)

    def flush_cost(self, state):
        return dist.dist_flush_cost(self.cfg, self.mesh, state)

    def lookup(self, state, keys):
        return dist.dist_lookup(self.cfg, self.mesh, state, keys)

    def count(self, state, k1, k2, plan: QueryPlan):
        return dist.dist_count(self.cfg, self.mesh, state, k1, k2, plan.max_candidates)

    def range(self, state, k1, k2, plan: QueryPlan):
        keys, vals, counts, ok = dist.dist_range(
            self.cfg, self.mesh, state, k1, k2, plan.max_candidates, plan.max_results
        )
        return dist.assemble_range(keys, vals, counts, ok, plan.max_results)

    def cleanup(self, state):
        return dist.dist_cleanup(self.cfg, self.mesh, state)

    def maintain_state(self, state, budget, *, only_if_debt=False):
        # Shard-local (zero-communication): `budget` bounds each shard's
        # compaction independently, mirroring dist_cleanup's locality.
        return dist.dist_maintain(
            self.cfg, self.mesh, state, budget, only_if_debt=only_if_debt
        )

    def size(self, state):
        return dist.dist_size(self.cfg, self.mesh, state)

    def overflowed(self, state):
        return jnp.any(state.overflowed)


@register_backend
@dataclasses.dataclass(frozen=True)
class SortedArrayBackend(Backend):
    """One sorted run: O(n) per batch update (the Table 2 baseline), same
    query semantics as the LSM via the shared run-based pipelines."""

    name = "sorted_array"
    caps = Capabilities(
        supports_updates=True,
        supports_deletes=True,
        supports_ordered_queries=True,
        supports_cleanup=True,
    )

    cfg: sa.SAConfig
    b: int  # facade batch width; the SA core itself accepts any width

    @classmethod
    def from_options(cls, *, capacity=None, batch_size=None, **extra):
        if extra:
            raise TypeError(f"unknown options for backend 'sorted_array': {sorted(extra)}")
        cap = int(capacity) if capacity is not None else 1 << 20
        b = int(batch_size) if batch_size is not None else min(1024, cap)
        return cls(sa.SAConfig(capacity=cap), b)

    @property
    def batch_size(self) -> int:
        return self.b

    @property
    def capacity(self) -> int:
        return self.cfg.capacity

    def init(self):
        return sa.sa_init(self.cfg)

    def bulk_build(self, keys, values):
        return sa.sa_bulk_build(self.cfg, keys, values)

    def update_encoded(self, state, key_vars, values):
        return sa.sa_update_batch(self.cfg, state, key_vars, values)

    def stage_encoded(self, state, key_vars, values, count):
        # No staging buffer: apply immediately with the recency sort — staged
        # elements are the newest run either way, so queries agree with the
        # buffered LSM backends lane-for-lane (flush_state is a no-op).
        return sa.sa_stage(self.cfg, state, key_vars, values, count)

    def occupancy(self, state):
        # No buffer, no debt tracker: everything lives in the one run. n
        # counts stale duplicates until the next update's recency merge.
        zero = jnp.zeros((), jnp.int32)
        return OccupancyStats(pending=zero, resident=state.n, debt=zero)

    def _runs(self, state):
        return [(state.key_vars, state.values)]

    def lookup(self, state, keys):
        return queries.lookup_runs(self._runs(state), keys)

    def count(self, state, k1, k2, plan: QueryPlan):
        return queries.count_runs(self._runs(state), k1, k2, plan.max_candidates)

    def range(self, state, k1, k2, plan: QueryPlan):
        return queries.range_runs(
            self._runs(state), k1, k2, plan.max_candidates, plan.max_results
        )

    def cleanup(self, state):
        return sa.sa_cleanup(self.cfg, state)

    def size(self, state):
        return queries.valid_count_runs(self._runs(state))

    def overflowed(self, state):
        return state.n > self.cfg.capacity


@register_backend
@dataclasses.dataclass(frozen=True)
class CuckooBackend(Backend):
    """Static cuckoo hash (CUDPP-style): O(1) lookups, bulk build only, no
    ordered queries — the entire point of the paper's Table 1 comparison."""

    name = "cuckoo"
    caps = Capabilities(
        supports_updates=False,
        supports_deletes=False,
        supports_ordered_queries=False,
        supports_cleanup=False,
    )

    cfg: ck.CuckooConfig
    declared_capacity: int

    @classmethod
    def from_options(
        cls, *, capacity=None, load_factor=0.8, seed=0, max_rounds=100,
        batch_size=None, **extra,
    ):
        if extra:
            raise TypeError(f"unknown options for backend 'cuckoo': {sorted(extra)}")
        del batch_size  # accepted for create() symmetry; meaningless here
        cap = int(capacity) if capacity is not None else 1 << 20
        table_size = max(int(cap / float(load_factor)), 1)
        return cls(
            ck.CuckooConfig(table_size=table_size, max_rounds=int(max_rounds), seed=int(seed)),
            cap,
        )

    @property
    def batch_size(self) -> int:
        return 1  # no incremental updates; facade never chunks for cuckoo

    @property
    def capacity(self) -> int:
        return self.declared_capacity

    def init(self):
        m = self.cfg.table_size
        return ck.CuckooTable(
            slot_keys=jnp.full((m,), ck.EMPTY, jnp.int32),
            slot_vals=jnp.zeros((m,), jnp.int32),
            build_ok=jnp.asarray(True),
        )

    def bulk_build(self, keys, values):
        return ck.cuckoo_build(self.cfg, keys, values)

    def lookup(self, state, keys):
        return ck.cuckoo_lookup(self.cfg, state, keys)

    def size(self, state):
        return jnp.sum(state.slot_keys != ck.EMPTY).astype(jnp.int32)

    def overflowed(self, state):
        return ~state.build_ok
