"""Unified dictionary API: one jit-native facade over every backend.

The paper benchmarks the GPU LSM as a *dictionary* against a sorted array and
a cuckoo hash table (Table 1); this package is the corresponding library
surface. `Dictionary.create(backend=...)` yields a pytree-registered handle
whose methods (insert / delete / update / bulk_build / lookup / count /
range / cleanup / size) hide all jit / donation / batching plumbing:

    from repro.api import Dictionary

    d = Dictionary.create("lsm", capacity=1 << 20)
    d = d.insert(keys, values)            # any length — padded/split into b-batches
    found, vals = d.lookup(queries)
    counts, ok = d.count(k1, k2)          # QueryPlan auto-sized, override available

Backend capability matrix (paper Table 1 — dictionary ops x data structure):

    op          lsm   sorted_array   cuckoo
    insert      yes   yes            no (static: bulk_build only)
    delete      yes   yes            no
    lookup      yes   yes            yes
    count       yes   yes            no (unordered)
    range       yes   yes            no (unordered)
    cleanup     yes   yes            no
    bulk_build  yes   yes            yes

Unsupported ops raise `CapabilityError` naming the backend and the backends
that do support the op — never a silent wrong answer.
"""

from repro.api.backend import (  # noqa: F401
    Backend,
    BackendState,
    Capabilities,
    CapabilityError,
    KeyDomainError,
    OccupancyStats,
    available_backends,
    get_backend_class,
    register_backend,
)
from repro.api.plan import QueryPlan  # noqa: F401
from repro.api.dictionary import Dictionary  # noqa: F401

# Importing the module registers the built-in backends.
from repro.api import backends as _builtin_backends  # noqa: F401,E402
