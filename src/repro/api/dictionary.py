"""`Dictionary`: the jit-native facade over every dictionary backend.

Design:

* **Pytree-registered handle.** A `Dictionary` is (static backend, dynamic
  state). The backend (frozen dataclass) rides in the treedef, the state in
  the leaves, so a `Dictionary` can cross jit/scan/shard_map boundaries and
  live inside larger pytrees (e.g. the serving page table).

* **Compiled-executable cache.** Every op runs through one module-level
  cache keyed on (backend, op, static plan); `jax.jit` then specializes per
  input shape under that key. Mutating ops donate the incoming state
  buffers, so the facade matches the hand-rolled
  `jax.jit(functools.partial(...), donate_argnums=0)` plumbing it replaces —
  users never touch jit, partial, or donation. Mutators are *linear*: the
  receiving handle is consumed (its buffers are donated) and the returned
  handle must be used from then on.

* **Coalescing batch contract.** The paper's update is rigidly b-wide; the
  facade accepts any length and *stages* it: real lanes compact to the front
  (arrival order preserved), split into b-wide sub-batches, and feed the
  backend's write buffer (`stage_encoded`) through a `lax.scan` (single
  chunk: direct call). Sub-batch updates no longer consume a batch slot each
  — a slot is consumed only when a buffer overflows b pending elements, on
  explicit `flush()`, or when the `flush_threshold` policy triggers.
  Duplicate keys resolve in strict arrival order (the write-buffer recency
  rule, docs/DESIGN.md §5): the later lane/call wins, including a later
  insert over an earlier tombstone. Partial lanes can be masked per-call via
  `valid=`; masked lanes never occupy buffer slots.

* **Key-domain validation.** Keys outside [0, MAX_USER_KEY] alias the
  placebo key or flip sign under the status-bit encoding and silently
  corrupt ordering; the facade raises `KeyDomainError` at the boundary
  whenever inputs are concrete (inside a user's jit trace the check is
  skipped — values do not exist yet).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.backend import (
    Backend,
    CapabilityError,
    KeyDomainError,
    get_backend_class,
)
from repro.api.plan import QueryPlan
from repro.core import semantics as sem
from repro.core.lsm import compact_real

# (backend, op, statics) -> jitted executable. jax.jit keeps the per-shape
# specialization under each entry, so this stays small: one entry per
# (config, op) the process touches.
_EXEC_CACHE: Dict[tuple, object] = {}


def _cached_exec(backend: Backend, op: str, fn, *, donate_state: bool = False, statics=()):
    key = (backend, op, statics)
    f = _EXEC_CACHE.get(key)
    if f is None:
        f = jax.jit(
            functools.partial(fn, backend, *statics),
            donate_argnums=(0,) if donate_state else (),
        )
        _EXEC_CACHE[key] = f
    return f


# -- op bodies (backend bound statically via the cache) -----------------------


def _exec_update(backend, flush_threshold, maintenance_budget, state, keys,
                 values, is_delete, valid):
    """Encode, front-compact, pad to k*b, and stage the sub-batches (scan
    when k > 1), then apply the optional flush-threshold policy.

    Everything from encoding onward runs inside the jitted executable so the
    eager path does no array work (the Table 2 timing protocol measures this
    whole pipeline as the update cost, like the hand-rolled jit it replaced).

    Lanes reach `stage_encoded` in arrival order with a per-chunk real-lane
    count: duplicates resolve strictly by sequence (later lane/call wins —
    the write-buffer recency rule), and masked-out lanes are compacted away
    so they never occupy buffer slots.

    Sharded backends need no special casing here: each b-wide sub-batch
    reaches `stage_encoded` whole (all-gathered under shard_map); every
    shard re-compacts its owned lanes into its local buffer, so arrival
    order is preserved per key owner.
    """
    kv = sem.encode(keys, is_delete)
    vals = jnp.where(is_delete, sem.EMPTY_VALUE, values)
    b = backend.batch_size
    n = keys.shape[0]
    if valid is not None:
        # compact_real drops masked lanes (placebo-prefilled scatter), so no
        # pre-masking is needed.
        kv, vals, total_real = compact_real(kv, vals, valid)
    else:
        total_real = jnp.asarray(n, jnp.int32)
    k = -(-n // b)
    pad = k * b - n
    if pad:
        kv = jnp.concatenate([kv, jnp.full((pad,), sem.PLACEBO_KV, jnp.int32)])
        vals = jnp.concatenate([vals, jnp.full((pad,), sem.EMPTY_VALUE, jnp.int32)])
    kv = kv.reshape(k, b)
    vals = vals.reshape(k, b)
    counts = jnp.clip(total_real - jnp.arange(k, dtype=jnp.int32) * b, 0, b)
    if k == 1:
        state = backend.stage_encoded(state, kv[0], vals[0], counts[0])
    else:
        def body(st, chunk):
            ckv, cval, cnt = chunk
            return backend.stage_encoded(st, ckv, cval, cnt), None

        state, _ = jax.lax.scan(body, state, (kv, vals, counts))
    if flush_threshold is not None:
        state = backend.flush_state(state, flush_threshold)
    if maintenance_budget is not None:
        # Piggybacked budgeted compaction: only_if_debt gates the work behind
        # a traced prefix-debt check, so debt-free updates pay one comparison.
        state = backend.maintain_state(state, maintenance_budget, only_if_debt=True)
    return state


def _exec_flush(backend, maintenance_budget, state):
    state = backend.flush_state(state)
    if maintenance_budget is not None:
        state = backend.maintain_state(state, maintenance_budget, only_if_debt=True)
    return state


def _exec_pending(backend, state):
    return backend.pending_count(state)


def _exec_occupancy(backend, state):
    return backend.occupancy(state)


def _exec_flush_cost(backend, state):
    return backend.flush_cost(state)


def _exec_bulk_build(backend, keys, values):
    return backend.bulk_build(keys, values)


def _exec_lookup(backend, state, keys):
    return backend.lookup(state, keys)


def _exec_count(backend, plan, state, k1, k2):
    return backend.count(state, k1, k2, plan)


def _exec_range(backend, plan, state, k1, k2):
    return backend.range(state, k1, k2, plan)


def _exec_cleanup(backend, state):
    return backend.cleanup(state)


def _exec_maintain(backend, budget, state):
    return backend.maintain_state(state, budget)


def _exec_size(backend, state):
    return backend.size(state)


# -- input hygiene ------------------------------------------------------------


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def _check_key_domain(name: str, keys, valid=None) -> None:
    """Raise KeyDomainError for concrete keys outside [0, MAX_USER_KEY].

    Runs on the *original* input (before any int32 cast) so overflow can't
    wrap a bad key back into range. Lanes masked out by `valid` are exempt.
    """
    if not _is_concrete(keys) or (valid is not None and not _is_concrete(valid)):
        return
    a = np.asarray(keys)
    if a.dtype.kind not in "iu":
        raise KeyDomainError(f"{name} must be an integer array, got dtype {a.dtype}")
    bad = (a.astype(np.int64) < 0) | (a.astype(np.int64) > sem.MAX_USER_KEY)
    if valid is not None:
        bad = bad & np.asarray(valid)
    if bad.any():
        examples = np.asarray(a[bad]).ravel()[:5].tolist()
        raise KeyDomainError(
            f"{name} outside the key domain [0, {sem.MAX_USER_KEY}]: {examples} — "
            "out-of-domain keys alias the placebo key or flip sign under the "
            "status-bit encoding and would silently corrupt ordering"
        )


def _as_keys(name: str, x):
    arr = jnp.asarray(x, jnp.int32)
    if arr.ndim == 0:
        arr = arr[None]
    if arr.ndim != 1:
        raise ValueError(f"{name} must be 1-D, got shape {arr.shape}")
    return arr


class Dictionary:
    """A dynamic dictionary handle: create once, thread through updates.

        d = Dictionary.create("lsm", capacity=1 << 20)
        d = d.insert(keys, values)      # consumes d's buffers (donation)
        found, vals = d.lookup(queries)

    All methods are jit-compiled internally and safe to call under an outer
    jit/scan (the handle is a pytree). Mutating methods return a NEW handle
    and donate the old one's buffers — keep only the returned handle.
    """

    __slots__ = ("_backend", "_state", "_validate", "_flush_threshold",
                 "_maintenance_budget")

    def __init__(self, backend: Backend, state, validate: bool = True,
                 flush_threshold: Optional[int] = None,
                 maintenance_budget: Optional[int] = None):
        self._backend = backend
        self._state = state
        self._validate = validate
        self._flush_threshold = flush_threshold
        self._maintenance_budget = maintenance_budget

    # -- construction --------------------------------------------------------

    @classmethod
    def create(cls, backend: str = "lsm", validate: bool = True,
               flush_threshold: Optional[int] = None,
               maintenance_budget: Optional[int] = None, **options) -> "Dictionary":
        """Empty dictionary:
        `create("lsm"|"lsm_sharded"|"sorted_array"|"cuckoo", ...)`.

        Common options: capacity, batch_size. Backend-specific: num_levels
        (lsm, lsm_sharded); num_shards, mesh, axis (lsm_sharded — see
        repro.api.backends for mesh/axis requirements); load_factor, seed,
        max_rounds (cuckoo). `validate=False` skips the host-side
        key-domain / uniqueness checks on concrete inputs (hot paths,
        benchmarks); capability errors always raise.

        `flush_threshold` (buffered backends): after every update, any write
        buffer holding >= flush_threshold staged elements is flushed into the
        main structure (1 = flush every call, the old pad-every-call
        latency/slot profile). Default None: buffers flush only on overflow,
        explicit `flush()`, or `cleanup()`.

        `maintenance_budget` (maintenance-capable backends): piggyback
        budgeted incremental compaction on every update/flush — at most
        `maintenance_budget` resident elements are touched per call, and a
        traced debt check skips the work entirely when there is nothing to
        reclaim. This keeps stale-element debt bounded without the
        stop-the-world `cleanup()` latency spike. `maintain()` can also be
        called explicitly at any time.
        """
        be = get_backend_class(backend).from_options(**options)
        if flush_threshold is not None:
            t = int(flush_threshold)
            if not 1 <= t <= be.batch_size:
                raise ValueError(
                    f"flush_threshold must be in [1, batch_size={be.batch_size}], got {t}"
                )
            flush_threshold = t
        if maintenance_budget is not None:
            if not be.caps.supports_maintenance:
                raise CapabilityError(be._no("maintain"))
            m = int(maintenance_budget)
            if m < 1:
                raise ValueError(f"maintenance_budget must be >= 1, got {m}")
            maintenance_budget = m
        return cls(be, be.init(), validate, flush_threshold, maintenance_budget)

    # -- static introspection ------------------------------------------------

    @property
    def backend(self) -> str:
        return self._backend.name

    @property
    def capabilities(self):
        return self._backend.caps

    @property
    def capacity(self) -> int:
        return self._backend.capacity

    @property
    def batch_size(self) -> int:
        return self._backend.batch_size

    @property
    def num_shards(self) -> int:
        """Device partitions behind this handle (1 unless backend is sharded)."""
        return self._backend.num_shards

    @property
    def buffered(self) -> bool:
        """Does this backend stage updates in a write buffer (pending/flush
        meaningful)? False for apply-immediately backends."""
        return self._backend.has_write_buffer

    @property
    def state(self):
        """The underlying core state (LSMState / SAState / CuckooTable)."""
        return self._state

    def __repr__(self) -> str:
        return (
            f"Dictionary(backend={self._backend.name!r}, "
            f"capacity={self.capacity}, batch_size={self.batch_size})"
        )

    # -- capability gate -----------------------------------------------------

    def _require(self, op: str, flag: bool) -> None:
        if not flag:
            raise CapabilityError(self._backend._no(op))

    def _evolve(self, new_state) -> "Dictionary":
        return Dictionary(self._backend, new_state, self._validate,
                          self._flush_threshold, self._maintenance_budget)

    # -- updates -------------------------------------------------------------

    def update(self, keys, values=None, is_delete=None, valid=None) -> "Dictionary":
        """Mixed batch of any length: insert where ~is_delete, tombstone
        where is_delete; `valid=False` lanes are compacted away (they never
        occupy write-buffer slots).

        Updates are *staged*: sub-batches coalesce in the backend's write
        buffer and consume a batch slot only when more than batch_size
        elements are pending (or on `flush()` / the flush_threshold policy).
        Duplicate keys resolve in strict arrival order — the later lane or
        call wins, including a later insert over an earlier tombstone (the
        write-buffer recency rule; staged entries are immediately visible to
        every query). Returns the new handle (the old one's buffers are
        donated).
        """
        caps = self._backend.caps
        self._require("update", caps.supports_updates)
        if self._validate:
            _check_key_domain("update keys", keys, valid)
        keys = _as_keys("keys", keys)
        n = keys.shape[0]
        if n == 0:
            return self

        if is_delete is None:
            is_delete = jnp.zeros((n,), bool)
        else:
            is_delete = jnp.asarray(is_delete, bool)
            if is_delete.ndim == 0:
                is_delete = jnp.broadcast_to(is_delete, keys.shape)
            if _is_concrete(is_delete) and bool(np.asarray(is_delete).any()):
                self._require("delete", caps.supports_deletes)
        if values is None:
            values = jnp.zeros((n,), jnp.int32)
        values = jnp.asarray(values, jnp.int32)
        if values.ndim == 0:
            values = jnp.broadcast_to(values, keys.shape)
        if values.shape != keys.shape or is_delete.shape != keys.shape:
            raise ValueError(
                f"keys/values/is_delete shapes differ: {keys.shape}/"
                f"{values.shape}/{is_delete.shape}"
            )
        if valid is not None:
            valid = jnp.asarray(valid, bool)

        f = _cached_exec(
            self._backend, "update", _exec_update,
            donate_state=True,
            statics=(self._flush_threshold, self._maintenance_budget),
        )
        new_state = f(self._state, keys, values, is_delete, valid)
        return self._evolve(new_state)

    def insert(self, keys, values, valid=None) -> "Dictionary":
        """Insert (key, value) pairs; newer values win on duplicate keys."""
        return self.update(keys, values, valid=valid)

    def delete(self, keys, valid=None) -> "Dictionary":
        """Delete keys via tombstones (paper §3.3).

        Keys are passed through unchanged so domain validation sees the
        original values (an early int32 cast would let out-of-range keys
        wrap silently and tombstone the wrong key).
        """
        # Gate on 'delete' here so the error names the op the user called
        # (update()'s own gate would report 'update' for e.g. cuckoo).
        self._require("delete", self._backend.caps.supports_deletes)
        return self.update(keys, is_delete=True, valid=valid)

    def bulk_build(self, keys, values) -> "Dictionary":
        """Replace contents with n unique keys in one sort-and-segment pass
        (paper §5.2). n need not be a multiple of batch_size."""
        self._require("bulk_build", self._backend.caps.supports_bulk_build)
        if self._validate:
            _check_key_domain("bulk_build keys", keys)
        keys = _as_keys("keys", keys)
        if self._validate and _is_concrete(keys):
            arr = np.asarray(keys)
            if len(np.unique(arr)) != arr.shape[0]:
                raise ValueError("bulk_build requires unique keys (paper §5.2)")
        values = jnp.asarray(values, jnp.int32)
        f = _cached_exec(self._backend, "bulk_build", _exec_bulk_build)
        return self._evolve(f(keys, values))

    def cleanup(self) -> "Dictionary":
        """Purge stale elements and tombstones (paper §3.6/§4.5).

        Buffered backends fold staged updates into the compaction (the
        cleanup-boundary flush) — afterwards `pending()` is 0 and no batch
        slot was wasted on a partial batch."""
        self._require("cleanup", self._backend.caps.supports_cleanup)
        f = _cached_exec(self._backend, "cleanup", _exec_cleanup, donate_state=True)
        return self._evolve(f(self._state))

    def maintain(self, budget: Optional[int] = None) -> "Dictionary":
        """Budgeted incremental compaction: reclaim stale elements touching at
        most `budget` residents (STATIC Python int; each distinct budget
        compiles one executable).

        Precedence: an explicit `budget` wins; otherwise the handle's
        configured `maintenance_budget`; otherwise None — which degrades to a
        full `cleanup()` (maintain(∞) IS cleanup, minus the buffer fold).
        Queries are exact at every budget level — maintenance is
        observationally invisible. Sharded backends maintain shard-locally
        (zero communication; the budget bounds each shard independently).
        Returns the new handle (the old one's buffers are donated).
        """
        self._require("maintain", self._backend.caps.supports_maintenance)
        if budget is None:
            budget = self._maintenance_budget
        else:
            budget = int(budget)
            if budget < 1:
                raise ValueError(f"maintain budget must be >= 1, got {budget}")
        f = _cached_exec(
            self._backend, "maintain", _exec_maintain,
            donate_state=True, statics=(budget,),
        )
        return self._evolve(f(self._state))

    def flush(self) -> "Dictionary":
        """Push staged (write-buffer) updates into the main structure.

        No-op for backends without a write buffer and for empty buffers. A
        partial buffer is placebo-padded to a full batch, consuming one batch
        slot — the cost the coalescing update path defers. Returns the new
        handle (the old one's buffers are donated)."""
        f = _cached_exec(
            self._backend, "flush", _exec_flush,
            donate_state=True, statics=(self._maintenance_budget,),
        )
        return self._evolve(f(self._state))

    def pending(self):
        """Staged-but-unflushed element count (int32 scalar; 0 if unbuffered).

        For sharded backends this sums the shard-local buffers."""
        f = _cached_exec(self._backend, "pending", _exec_pending)
        return f(self._state)

    def occupancy(self):
        """OccupancyStats(pending, resident, debt) — structural counters for
        serving schedulers (repro.serve.server's admission/flush policy).

        Reads counters the state already carries (no query machinery), so
        polling between coalesced device steps is cheap: `pending` is the
        write-buffer occupancy, `resident` the main-structure elements (stale
        included — r*b for the LSM), `debt` the reclaimable-stale estimate
        that `maintain()` budgets against. Sharded backends psum all three."""
        f = _cached_exec(self._backend, "occupancy", _exec_occupancy)
        return f(self._state)

    def flush_cost_estimate(self):
        """Estimated elements a `flush()` would touch now (int32 scalar; 0
        when nothing is staged or the backend has no buffer).

        For the LSM this is the cascade merge the carried batch triggers —
        b * (trailing_ones(r) + 1) — so a scheduler can tell a cheap flush
        (empty low levels) from one that will cascade deep, and time forced
        flushes accordingly. Sharded backends sum the shard-local costs."""
        f = _cached_exec(self._backend, "flush_cost", _exec_flush_cost)
        return f(self._state)

    # -- queries -------------------------------------------------------------

    def lookup(self, keys) -> Tuple[jax.Array, jax.Array]:
        """Batched LOOKUP -> (found: bool[nq], values: int32[nq])."""
        if self._validate:
            _check_key_domain("lookup keys", keys)
        keys = _as_keys("keys", keys)
        f = _cached_exec(self._backend, "lookup", _exec_lookup)
        return f(self._state, keys)

    def _resolved_plan(self, plan: Optional[QueryPlan]) -> QueryPlan:
        return (plan or QueryPlan()).resolved(self._backend.max_query_candidates)

    def count(self, k1, k2, plan: Optional[QueryPlan] = None):
        """COUNT(k1, k2) per query -> (counts: int32[nq], ok: bool[nq]).

        ok=False flags truncation by the plan — re-issue with an explicit
        larger QueryPlan for exactness.
        """
        self._require("count", self._backend.caps.supports_ordered_queries)
        if self._validate:
            _check_key_domain("count k1", k1)
            _check_key_domain("count k2", k2)
        k1, k2 = _as_keys("k1", k1), _as_keys("k2", k2)
        p = self._resolved_plan(plan)
        f = _cached_exec(self._backend, "count", _exec_count, statics=(p,))
        return f(self._state, k1, k2)

    def range(self, k1, k2, plan: Optional[QueryPlan] = None):
        """RANGE(k1, k2) -> (keys [nq, max_results], values, counts, ok).

        Rows are ascending by key and placebo-padded beyond counts[i].
        """
        self._require("range", self._backend.caps.supports_ordered_queries)
        if self._validate:
            _check_key_domain("range k1", k1)
            _check_key_domain("range k2", k2)
        k1, k2 = _as_keys("k1", k1), _as_keys("k2", k2)
        p = self._resolved_plan(plan)
        f = _cached_exec(self._backend, "range", _exec_range, statics=(p,))
        return f(self._state, k1, k2)

    def size(self):
        """Live (visible) element count, int32 scalar (stale excluded)."""
        f = _cached_exec(self._backend, "size", _exec_size)
        return f(self._state)

    def overflowed(self):
        """bool scalar — did any update exceed the static capacity?"""
        return self._backend.overflowed(self._state)


def _dict_flatten(d: Dictionary):
    return (d._state,), (
        d._backend, d._validate, d._flush_threshold, d._maintenance_budget
    )


def _dict_unflatten(aux, children):
    backend, validate, flush_threshold, maintenance_budget = aux
    obj = object.__new__(Dictionary)
    object.__setattr__(obj, "_backend", backend)
    object.__setattr__(obj, "_state", children[0])
    object.__setattr__(obj, "_validate", validate)
    object.__setattr__(obj, "_flush_threshold", flush_threshold)
    object.__setattr__(obj, "_maintenance_budget", maintenance_budget)
    return obj


jax.tree_util.register_pytree_node(Dictionary, _dict_flatten, _dict_unflatten)
