"""QueryPlan: the static sizing contract for COUNT/RANGE queries.

The fixed-shape count/range pipeline (core/queries.py) needs two static
bounds: `max_candidates` (stage-3 gather tile width) and `max_results`
(range output width). The paper's kernels take them as ad-hoc positional
ints; the facade bundles them into a hashable dataclass so they can key the
compiled-executable cache, carry an auto-sizing heuristic, and stay
overridable in one place.

Results carry an `ok` flag: False means the plan's bounds truncated the
answer — re-issue with a bigger explicit plan for exactness.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Static sizing for ordered queries. `None` fields are auto-sized.

    max_candidates: per-query candidate-tile width (paper stage 3). Bounds
      the stale+live elements a single [k1, k2] interval may overlap.
    max_results: per-query RANGE output width (ignored by COUNT).
    """

    max_candidates: Optional[int] = None
    max_results: Optional[int] = None

    def __post_init__(self):
        for f in ("max_candidates", "max_results"):
            v = getattr(self, f)
            if v is not None and v < 1:
                raise ValueError(f"{f} must be >= 1, got {v}")

    def resolved(self, max_candidate_bound: int) -> "QueryPlan":
        """Concrete plan for a dictionary whose queries can overlap at most
        `max_candidate_bound` elements (static capacity plus any write-buffer
        slots — `Backend.max_query_candidates`; clamping to bare capacity
        would make a full-structure query inexact with no plan able to fix
        it once the buffer holds residents).

        Heuristic: exact (full bound) while the tile stays small (<= 4096);
        beyond that, the power of two at ~bound/4 (min 4096) — a bounded
        tile that is still generous for the paper's query widths (expected
        range lengths 8..1024). `ok=False` in results signals the heuristic
        was too small for a particular query mix.
        """
        bound = max_candidate_bound
        mc = self.max_candidates
        if mc is None:
            mc = bound if bound <= 4096 else max(4096, 1 << (bound.bit_length() - 3))
        mc = min(mc, bound)
        mr = self.max_results if self.max_results is not None else mc
        return QueryPlan(max_candidates=mc, max_results=mr)
