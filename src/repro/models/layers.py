"""Shared model layers: norms, RoPE, attention (GQA, blocked, cached), MLP, MoE.

Conventions:
  * params are nested dicts of jnp arrays; every layer is `init(key, ...)` +
    a pure apply function.
  * compute dtype is bf16; reductions that need it (softmax, norms, router)
    run in fp32.
  * attention KV caches are dicts {"k": [B, S_max, KV, hd], "v": ..., "len": []}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DTYPE = jnp.bfloat16
# Trace-time switch (set via set_attn_seq_shard / PerfOptions.attn_seq_shard):
# shard attention activations by SEQUENCE over the model axis. For GQA archs
# whose head counts do not divide the TP axis (e.g. 28 q / 4 kv heads on a
# 16-way axis) the partitioner otherwise pads or replicates heads and emits
# large reshard collectives; sequence is always divisible.
_ATTN_SEQ_SHARD = False


def set_attn_seq_shard(enabled: bool) -> None:
    global _ATTN_SEQ_SHARD
    _ATTN_SEQ_SHARD = bool(enabled)
NEG_INF = -1e30
# Sequence length above which causal attention switches to the Q-blocked
# streaming form (bounds the scores buffer to Q_BLOCK rows).
BLOCKED_ATTN_THRESHOLD = 8192
Q_BLOCK = 1024


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, bias=False, scale=0.02):
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(DTYPE)}
    if bias:
        p["b"] = jnp.zeros((d_out,), DTYPE)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rms_norm_init(d):
    return {"scale": jnp.ones((d,), DTYPE)}


def rms_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta):
    """Rotary embedding. x: [..., S, H, hd]; positions: [S] or [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def _act(name, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# MLP (GLU for silu, plain for gelu)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, act="silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU
        return {
            "w_gate": dense_init(k1, d_model, d_ff),
            "w_up": dense_init(k2, d_model, d_ff),
            "w_down": dense_init(k3, d_ff, d_model),
            "act": None,
        }
    return {
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
        "act": None,
    }


def mlp(p, x, act="silu"):
    if "w_gate" in p:
        h = _act("silu", dense(p["w_gate"], x)) * dense(p["w_up"], x)
    else:
        h = _act(act, dense(p["w_up"], x))
    return dense(p["w_down"], h)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_init(key, d_model, num_heads, num_kv_heads, head_dim, qkv_bias=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, bias=qkv_bias),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, bias=qkv_bias),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, bias=qkv_bias),
        "wo": dense_init(ko, num_heads * head_dim, d_model),
    }


def _sdpa(q, k, v, mask):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; mask: broadcastable [B,1,Sq,Sk]."""
    b, sq, h, hd = q.shape
    kv_heads = k.shape[2]
    groups = h // kv_heads
    qg = q.reshape(b, sq, kv_heads, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _causal_mask(sq, sk, q_offset=0, window=0):
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window:
        m = m & (ki > qi - window)
    return m[None, None]  # [1,1,Sq,Sk]


def attention(p, x, positions, *, num_heads, num_kv_heads, head_dim, theta,
              causal=True, window=0):
    """Full (or Q-blocked) self-attention for train/prefill."""
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, num_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, s, num_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, s, num_kv_heads, head_dim)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if _ATTN_SEQ_SHARD:
        from repro.dist.sharding import hint

        # Q rows sequence-sharded over the TP axis; K/V replicated across it
        # (cheap: kv_heads is small for GQA). Each shard computes its own
        # causal score rows — flash-style row partitioning, no head padding.
        q = hint(q, ("pod", "data"), "model", None, None)
        k = hint(k, ("pod", "data"), None, None, None)
        v = hint(v, ("pod", "data"), None, None, None)

    if causal and s > BLOCKED_ATTN_THRESHOLD and s % Q_BLOCK == 0:
        # Q-blocked streaming attention: bounds the score buffer to
        # [B, H, Q_BLOCK, S] regardless of sequence length.
        nq = s // Q_BLOCK

        def body(carry, qi):
            q_blk = jax.lax.dynamic_slice_in_dim(q, qi * Q_BLOCK, Q_BLOCK, axis=1)
            mask = _causal_mask(Q_BLOCK, s, q_offset=qi * Q_BLOCK, window=window)
            o_blk = _sdpa(q_blk, k, v, mask)
            return carry, o_blk

        _, blocks = jax.lax.scan(body, None, jnp.arange(nq))
        out = jnp.moveaxis(blocks, 0, 1).reshape(b, s, num_heads, head_dim)
    else:
        mask = _causal_mask(s, s, window=window) if causal else jnp.ones((1, 1, s, s), bool)
        out = _sdpa(q, k, v, mask)
    return dense(p["wo"], out.reshape(b, s, num_heads * head_dim))


def attention_prefill(p, x, positions, *, num_heads, num_kv_heads, head_dim, theta,
                      window=0, cache_pad_to=0):
    """Prefill: same as attention() but also returns the populated KV cache.

    cache_pad_to > s reserves room in the cache for subsequent decode appends.
    """
    b, s, _ = x.shape
    k = rope(dense(p["wk"], x).reshape(b, s, num_kv_heads, head_dim), positions, theta)
    v = dense(p["wv"], x).reshape(b, s, num_kv_heads, head_dim)
    y = attention(p, x, positions, num_heads=num_heads, num_kv_heads=num_kv_heads,
                  head_dim=head_dim, theta=theta, causal=True, window=window)
    if cache_pad_to and cache_pad_to > s:
        pad = cache_pad_to - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return y, {"k": k, "v": v}


def attention_decode(p, x, cache, cache_len, *, num_heads, num_kv_heads, head_dim,
                     theta, window=0):
    """One-token decode against a KV cache.

    x: [B, 1, D]; cache: {"k","v"}: [B, S_max, KV, hd]; cache_len: [] int32 —
    number of valid positions already in the cache.
    """
    b, one, _ = x.shape
    s_max = cache["k"].shape[1]
    pos = jnp.full((1,), cache_len, dtype=jnp.int32)
    q = rope(dense(p["wq"], x).reshape(b, 1, num_heads, head_dim), pos, theta)
    k_new = rope(dense(p["wk"], x).reshape(b, 1, num_kv_heads, head_dim), pos, theta)
    v_new = dense(p["wv"], x).reshape(b, 1, num_kv_heads, head_dim)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), cache_len, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), cache_len, axis=1)

    ki = jnp.arange(s_max)[None, :]
    mask = ki <= cache_len
    if window:
        mask = mask & (ki > cache_len - window)
    out = _sdpa(q, k, v, mask[:, None, None, :] if mask.ndim == 2 else mask)
    y = dense(p["wo"], out.reshape(b, 1, num_heads * head_dim))
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MoE (sort-based ragged dispatch with static capacity — MegaBlocks-style)
# ---------------------------------------------------------------------------


def moe_init(key, d_model, num_experts, d_ff, num_shared=0, shared_d_ff=0):
    kr, ke, ks = jax.random.split(key, 3)
    k1, k2, k3 = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, d_model, num_experts),
        "w_gate": (jax.random.normal(k1, (num_experts, d_model, d_ff)) * 0.02).astype(DTYPE),
        "w_up": (jax.random.normal(k2, (num_experts, d_model, d_ff)) * 0.02).astype(DTYPE),
        "w_down": (jax.random.normal(k3, (num_experts, d_ff, d_model)) * 0.02).astype(DTYPE),
    }
    if num_shared:
        p["shared"] = mlp_init(ks, d_model, shared_d_ff or d_ff)
    return p


def moe(p, x, *, num_experts, top_k, capacity_factor=1.25):
    """Token-choice top-k MoE with static capacity.

    Dispatch is sort-based: (expert, token) assignments are sorted by expert,
    each expert processes a fixed-capacity contiguous slice (overflow tokens
    are dropped, as in GShard/Switch), expert FFNs run as one block-diagonal
    batched GEMM [E, C, D] x [E, D, F] that shards cleanly over the expert
    (model) axis.
    """
    b, s, d = x.shape
    n = b * s
    xt = x.reshape(n, d)
    m = n * top_k
    capacity = int(np.ceil(m / num_experts * capacity_factor))
    # Keep the expert GEMM well-formed even for tiny smoke configs.
    capacity = max(capacity, 8)

    logits = (xt @ p["router"]["w"].astype(jnp.float32)).astype(jnp.float32)  # [N, E]
    gates_all = jax.nn.softmax(logits, axis=-1)
    # top_k for indices only; gate values are recovered through a one-hot
    # einsum so the gradient path avoids batched-gather VJPs (top_k/
    # take_along_axis) — the selection itself is a straight-through constant.
    _, expert_ids = jax.lax.top_k(jax.lax.stop_gradient(logits), top_k)  # [N, K]
    sel_onehot = jax.nn.one_hot(expert_ids, num_experts, dtype=jnp.float32)  # [N,K,E]
    gate_vals = jnp.einsum("ne,nke->nk", gates_all, sel_onehot)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert_ids.reshape(m).astype(jnp.int32)
    flat_token = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, top_k)).reshape(m)

    # Sort integer ids only (no float operand => no sort VJP); the gate for
    # each sorted assignment is re-gathered by assignment id, whose gradient
    # is a plain 1-D scatter-add.
    assign_id = jnp.arange(m, dtype=jnp.int32)
    sort_e, sort_t, sort_a = jax.lax.sort(
        (flat_expert, flat_token, assign_id), dimension=0, is_stable=True, num_keys=1
    )
    sort_g = gate_vals.reshape(m)[sort_a]
    group_start = jnp.searchsorted(sort_e, jnp.arange(num_experts, dtype=jnp.int32), side="left")
    pos_in_group = jnp.arange(m, dtype=jnp.int32) - group_start[sort_e]
    valid = pos_in_group < capacity
    slot = jnp.where(valid, sort_e * capacity + pos_in_group, num_experts * capacity)

    gathered = xt[sort_t]  # [M, D]
    buf = jnp.zeros((num_experts * capacity, d), xt.dtype).at[slot].set(gathered, mode="drop")
    buf = buf.reshape(num_experts, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["w_up"]
    )
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(num_experts * capacity, d)

    slot_c = jnp.minimum(slot, num_experts * capacity - 1)
    contrib = out[slot_c] * (sort_g * valid).astype(out.dtype)[:, None]
    y = jnp.zeros((n, d), out.dtype).at[sort_t].add(contrib)

    if "shared" in p:
        y = y + mlp(p["shared"], xt)

    # Load-balance diagnostics (Switch aux loss), returned as metric.
    me = jnp.mean(gates_all, axis=0)
    ce = jnp.sum(sel_onehot, axis=(0, 1)) / m
    aux = num_experts * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux
