"""Mamba-2 SSD block (arXiv:2405.21060) — chunked matmul form + decode recurrence.

Train/prefill run the chunk-parallel SSD algorithm: intra-chunk attention-like
blocks are dense einsums (MXU-friendly), inter-chunk state propagation is an
associative scan over chunks — O(S) work, sub-quadratic sequence mixing, which
is why the ssm/hybrid architectures run the long_500k shape.

Decode is the O(1) recurrence over the (conv_state, ssm_state) cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import DTYPE, dense, dense_init, rms_norm, rms_norm_init


def mamba2_init(key, cfg):
    d = cfg.d_model
    d_inner = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state_dim
    g = cfg.ssm_n_groups
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(key, 5)
    return {
        # order: [z (d_inner), x (d_inner), B (g*n), C (g*n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * g * n + h),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim)) * 0.1).astype(DTYPE),
        "conv_b": jnp.zeros((conv_dim,), DTYPE),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "norm": rms_norm_init(d_inner),
        "out_proj": dense_init(ks[2], d_inner, d),
    }


def _split_proj(cfg, zxbcdt):
    d_inner = cfg.d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state_dim, cfg.ssm_heads
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : 2 * d_inner + 2 * g * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * g * n :]
    return z, xbc, dt


def _causal_conv(w, b, xbc):
    """Depthwise causal conv1d, kernel k. xbc: [B, S, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, B, C, chunk):
    """SSD Algorithm 1. x:[b,s,h,p] dt:[b,s,h] A:[h] B,C:[b,s,n] (groups=1).

    Returns (y:[b,s,h,p], final_state:[b,h,p,n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    z = s // chunk
    xc = x.reshape(b, z, chunk, h, p)
    dtc = dt.reshape(b, z, chunk, h)
    Bc = B.reshape(b, z, chunk, n)
    Cc = C.reshape(b, z, chunk, n)

    dtA = dtc * A[None, None, None, :]              # [b,z,c,h], negative
    cum = jnp.cumsum(dtA, axis=2)                   # within-chunk cumulative

    # Intra-chunk (diagonal) blocks.
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [b,z,i,j,h]
    ij_mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])[None, None, :, :, None]
    L = jnp.where(ij_mask, jnp.exp(seg), 0.0)                 # [b,z,i,j,h]
    cb = jnp.einsum("bzin,bzjn->bzij", Cc, Bc)                # [b,z,i,j]
    w = cb[..., None] * L * dtc[:, :, None, :, :]             # [b,z,i,j,h]
    y_diag = jnp.einsum("bzijh,bzjhp->bzihp", w.astype(x.dtype), xc)

    # Per-chunk end states.
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)           # [b,z,c,h]
    states = jnp.einsum(
        "bzcn,bzch,bzchp->bzhpn", Bc, (decay_states * dtc).astype(x.dtype), xc
    )                                                          # [b,z,h,p,n]

    # Inter-chunk associative scan: state_z = decay_z * state_{z-1} + states_z.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # [b,z,h]

    def combine(a, b_):
        d1, s1 = a
        d2, s2 = b_
        return d1 * d2, s1 * d2[..., None, None].astype(s1.dtype) + s2

    dec_scan, state_scan = jax.lax.associative_scan(combine, (chunk_decay, states), axis=1)
    final_state = state_scan[:, -1]                           # [b,h,p,n]
    # State *entering* chunk z (exclusive scan).
    prev = jnp.concatenate([jnp.zeros_like(state_scan[:, :1]), state_scan[:, :-1]], axis=1)

    y_off = jnp.einsum("bzin,bzhpn,bzih->bzihp", Cc, prev, jnp.exp(cum).astype(x.dtype))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2_forward(p, x, cfg, *, return_cache=False):
    """Train/prefill. x: [B, S, d_model].

    Sequences that are not a multiple of ssm_chunk are padded with dt=0 steps:
    exp(0*A)=1 and dt*B(x)x=0, so padding neither decays nor perturbs the
    state — the returned final_state is exact for the true length.
    """
    b, s, _ = x.shape
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_n_groups
    z, xbc_raw, dt_raw = _split_proj(cfg, dense(p["in_proj"], x))
    xbc = _causal_conv(p["conv_w"], p["conv_b"], xbc_raw)
    sp = s + (-s) % cfg.ssm_chunk
    pad = sp - s
    if pad:
        xbc_p = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt_raw_p = jnp.pad(dt_raw, ((0, 0), (0, pad), (0, 0)))
    else:
        xbc_p, dt_raw_p = xbc, dt_raw
    xs = xbc_p[..., : cfg.d_inner].reshape(b, sp, h, pdim)
    Bm = xbc_p[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, sp, n)
    Cm = xbc_p[..., cfg.d_inner + g * n :].reshape(b, sp, n)
    dt = jax.nn.softplus(dt_raw_p.astype(jnp.float32) + p["dt_bias"])
    if pad:
        seq_mask = (jnp.arange(sp) < s)[None, :, None]
        dt = dt * seq_mask
    A = -jnp.exp(p["A_log"])

    y, final_state = _ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, sp, cfg.d_inner)[:, :s]
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y)
    if return_cache:
        k = cfg.conv_kernel
        conv_state = jax.lax.dynamic_slice_in_dim(
            jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0))), s, k - 1, axis=1
        )
        return out, {"conv": conv_state, "ssm": final_state}
    return out


def mamba2_decode(p, x, cache, cfg):
    """One-token recurrence. x: [B, 1, d_model]; cache: {"conv","ssm"}."""
    b = x.shape[0]
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state_dim, cfg.ssm_n_groups
    k = cfg.conv_kernel
    z, xbc_new, dt_raw = _split_proj(cfg, dense(p["in_proj"], x))

    # conv cache: [B, k-1, conv_dim] of pre-activation inputs.
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # [B, k, conv_dim]
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    new_conv = window[:, 1:]

    xs = conv_out[..., : cfg.d_inner].reshape(b, h, pdim)
    Bm = conv_out[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, n)
    Cm = conv_out[..., cfg.d_inner + g * n :].reshape(b, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B, h]
    A = -jnp.exp(p["A_log"])

    dA = jnp.exp(dt * A[None, :])                                  # [B, h]
    state = cache["ssm"] * dA[..., None, None].astype(cache["ssm"].dtype)
    state = state + jnp.einsum("bn,bh,bhp->bhpn", Bm, dt.astype(x.dtype), xs)
    y = jnp.einsum("bn,bhpn->bhp", Cm, state)
    y = y + p["D"][None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, 1, cfg.d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), {"conv": new_conv, "ssm": state}
