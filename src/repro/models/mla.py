"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Train/prefill use the naive (decompressed) formulation; decode uses the
weight-absorbed formulation, attending directly over the cached latent
(c_kv [B, S, kv_lora] + k_pe [B, S, rope_dim]) without ever materializing
per-head K/V for the full context — this is MLA's entire point, and on TPU it
converts the decode KV stream from H*(nope+v) dims per token to
(kv_lora + rope) dims per token (a ~14x HBM-traffic cut for V3's shapes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import DTYPE, NEG_INF, dense, dense_init, rms_norm, rms_norm_init, rope


def mla_init(key, cfg):
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_head = qk_nope + qk_rope
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], d, cfg.q_lora_rank),
        "q_norm": rms_norm_init(cfg.q_lora_rank),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, h * q_head),
        "wkv_a": dense_init(ks[2], d, cfg.kv_lora_rank + qk_rope),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank),
        "w_uk": (jax.random.normal(ks[3], (cfg.kv_lora_rank, h, qk_nope)) * 0.02).astype(DTYPE),
        "w_uv": (jax.random.normal(ks[4], (cfg.kv_lora_rank, h, v_dim)) * 0.02).astype(DTYPE),
        "wo": dense_init(ks[5], h * v_dim, d),
    }


def _project_latent(p, x, positions, cfg):
    """Shared front half: q heads + latent (c_kv, k_pe)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_nope, qk_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = dense(p["wq_b"], rms_norm(p["q_norm"], dense(p["wq_a"], x), cfg.norm_eps))
    q = q.reshape(b, s, h, qk_nope + qk_rope)
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = rope(q_pe, positions, cfg.rope_theta)

    kv = dense(p["wkv_a"], x)
    c_kv = rms_norm(p["kv_norm"], kv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_pe = kv[..., cfg.kv_lora_rank :].reshape(b, s, 1, qk_rope)
    k_pe = rope(k_pe, positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_pe, c_kv, k_pe


def mla_attention(p, x, positions, cfg, *, causal=True, return_cache=False,
                  cache_pad_to=0):
    """Naive (decompressed) MLA for train/prefill."""
    b, s, _ = x.shape
    h = cfg.num_heads
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope, q_pe, c_kv, k_pe = _project_latent(p, x, positions, cfg)

    k_nope = jnp.einsum("bsl,lhn->bshn", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhv->bshv", c_kv, p["w_uv"])

    scores = (
        jnp.einsum("bqhn,bshn->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhr,bsr->bhqs", q_pe, k_pe)
    ).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(s)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshv->bqhv", probs, v)
    y = dense(p["wo"], out.reshape(b, s, h * cfg.v_head_dim))
    if return_cache:
        if cache_pad_to and cache_pad_to > s:
            pad = cache_pad_to - s
            c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
            k_pe = jnp.pad(k_pe, ((0, 0), (0, pad), (0, 0)))
        return y, {"c_kv": c_kv, "k_pe": k_pe}
    return y


def mla_decode(p, x, cache, cache_len, cfg):
    """Weight-absorbed single-token decode over the latent cache.

    scores = q_nope' c_kv^T + q_pe k_pe^T   with q_nope' = q_nope W_uk
    out    = (probs c_kv) W_uv              — no per-head K/V materialization.
    """
    b, one, _ = x.shape
    h = cfg.num_heads
    s_max = cache["c_kv"].shape[1]
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    pos = jnp.full((1,), cache_len, dtype=jnp.int32)
    q_nope, q_pe, c_kv_new, k_pe_new = _project_latent(p, x, pos, cfg)

    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), cache_len, axis=1
    )
    k_pe = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pe"], k_pe_new.astype(cache["k_pe"].dtype), cache_len, axis=1
    )

    q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["w_uk"])  # [B,1,H,kv_lora]
    scores = (
        jnp.einsum("bqhl,bsl->bhqs", q_abs, c_kv)
        + jnp.einsum("bqhr,bsr->bhqs", q_pe, k_pe)
    ).astype(jnp.float32) * scale
    mask = jnp.arange(s_max)[None, None, None, :] <= cache_len
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_latent = jnp.einsum("bhqs,bsl->bqhl", probs, c_kv)
    out = jnp.einsum("bqhl,lhv->bqhv", out_latent, p["w_uv"])
    y = dense(p["wo"], out.reshape(b, 1, h * cfg.v_head_dim))
    return y, {"c_kv": c_kv, "k_pe": k_pe}
