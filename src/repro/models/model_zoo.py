"""Top-level model API: init / train / prefill / decode for every arch family.

All entry points are pure jax functions of (cfg, params, batch) so they work
under jit, eval_shape (abstract init for the 671B dry-run), and pjit sharding.

Batch dicts ("extra" inputs are the modality stubs the assignment specifies):
  train   : tokens [B,St] int32, labels [B,St] int32
            (+ patch_embeds [B,P,D] bf16 for vlm; frames [B,Se,D] bf16 for audio)
  prefill : tokens [B,S] (+ stubs)
  decode  : token [B,1], caches (from prefill), cache_len [] int32
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.dist.sharding import hint
from repro.models import layers as L
from repro.models import transformer as T
from repro.train.options import PerfOptions, resolve as resolve_options

# Encoder frame count for the audio (enc-dec) architecture, all shapes.
AUDIO_ENC_LEN = 4096


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(L.DTYPE),
        "final_norm": L.rms_norm_init(cfg.d_model),
        "lm_head": (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(L.DTYPE),
    }
    plan = T.decoder_plan(cfg)
    gkeys = jax.random.split(ks[2], len(plan))
    params["groups"] = [
        T.group_init(gkeys[i], cfg, count, descs, cross=cfg.is_encoder_decoder)
        for i, (count, descs) in enumerate(plan)
    ]
    if cfg.has_vision_stub:
        params["vision_proj"] = L.dense_init(ks[3], cfg.d_model, cfg.d_model)
    if cfg.is_encoder_decoder:
        params["enc_groups"] = [
            T.group_init(ks[4], cfg, cfg.num_encoder_layers, [("attn", "mlp")])
        ]
        params["enc_final_norm"] = L.rms_norm_init(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------


def _encode(cfg, params, frames, options=None):
    """Audio encoder over stub frame embeddings (bidirectional)."""
    opts = resolve_options(options)
    x = frames.astype(L.DTYPE)
    positions = jnp.arange(x.shape[1])
    for gp, (count, descs) in zip(params["enc_groups"], [(cfg.num_encoder_layers, [("attn", "mlp")])]):
        x, _ = T.group_apply_train(cfg, gp, descs, x, positions, causal=False,
                                   remat_policy=opts.remat_policy, unroll=opts.scan_unroll,
                                   zero3_gather=opts.zero3_gather)
    return L.rms_norm(params["enc_final_norm"], x, cfg.norm_eps)


def _embed_inputs(cfg, params, tokens, batch, opts=None):
    """Token embeddings (+ prepended projected patch embeddings for vlm)."""
    embed = params["embed"]
    if opts is not None and opts.zero3_gather:
        # ZeRO-3 regather: vocab stays TP-sharded; drop the FSDP dim so the
        # token gather does not reshard the batch (DESIGN.md §6 / §Perf H2).
        embed = hint(embed, "model", None)
    x = embed[tokens]
    n_prefix = 0
    if cfg.has_vision_stub:
        pe = L.dense(params["vision_proj"], batch["patch_embeds"].astype(L.DTYPE))
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = pe.shape[1]
    return x, n_prefix


def _head(cfg, params, x, opts=None):
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    lm_head = params["lm_head"]
    if opts is not None and opts.zero3_gather:
        # Contraction-dim FSDP sharding on the head makes the partitioner
        # replicate the batch for the logits matmul — and that replication
        # poisons the whole backward pass. Regather to TP-only instead.
        lm_head = hint(lm_head, None, "model")
    return x @ lm_head


# ---------------------------------------------------------------------------
# train / prefill / decode
# ---------------------------------------------------------------------------


def apply_train(cfg: ModelConfig, params, batch, options=None):
    """Returns (logits [B,St,V], aux_loss scalar)."""
    opts = resolve_options(options)
    L.set_attn_seq_shard(opts.attn_seq_shard)
    tokens = batch["tokens"]
    enc_out = _encode(cfg, params, batch["frames"], options) if cfg.is_encoder_decoder else None
    x, n_prefix = _embed_inputs(cfg, params, tokens, batch, opts)
    positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)
    for gp, (count, descs) in zip(params["groups"], T.decoder_plan(cfg)):
        x, a = T.group_apply_train(cfg, gp, descs, x, positions, enc_out=enc_out,
                                   remat_policy=opts.remat_policy, unroll=opts.scan_unroll,
                                   zero3_gather=opts.zero3_gather)
        aux = aux + a
    if n_prefix:
        x = x[:, n_prefix:]
    return _head(cfg, params, x, opts), aux


def apply_prefill(cfg: ModelConfig, params, batch, cache_pad_to=0, options=None):
    """Returns (last-position logits [B,V], caches).

    cache_pad_to reserves cache room for decode appends beyond the prompt."""
    opts = resolve_options(options)
    L.set_attn_seq_shard(opts.attn_seq_shard)
    tokens = batch["tokens"]
    enc_out = _encode(cfg, params, batch["frames"], options) if cfg.is_encoder_decoder else None
    x, n_prefix = _embed_inputs(cfg, params, tokens, batch, opts)
    positions = jnp.arange(x.shape[1])
    caches = []
    for gp, (count, descs) in zip(params["groups"], T.decoder_plan(cfg)):
        x, c = T.group_apply_prefill(cfg, gp, descs, x, positions, enc_out=enc_out,
                                     cache_pad_to=cache_pad_to, unroll=opts.scan_unroll,
                                     zero3_gather=opts.zero3_gather)
        caches.append(c)
    logits = _head(cfg, params, x[:, -1:], opts)[:, 0]
    return logits, caches


def apply_decode(cfg: ModelConfig, params, token, caches, cache_len, options=None):
    """One-token step. Returns (logits [B,V], new caches)."""
    opts = resolve_options(options)
    embed = params["embed"]
    if opts.zero3_gather:
        embed = hint(embed, "model", None)
    x = embed[token]  # [B, 1, D]
    new_caches = []
    for gp, c, (count, descs) in zip(params["groups"], caches, T.decoder_plan(cfg)):
        x, nc = T.group_apply_decode(cfg, gp, descs, x, c, cache_len,
                                     unroll=opts.scan_unroll,
                                     zero3_gather=opts.zero3_gather)
        new_caches.append(nc)
    return _head(cfg, params, x, opts)[:, 0], new_caches


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Abstract inputs for one (arch x shape) dry-run cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        st = s - cfg.num_patches if cfg.has_vision_stub else s
        batch = {
            "tokens": _sds((b, st), jnp.int32),
            "labels": _sds((b, st), jnp.int32),
        }
        if cfg.has_vision_stub:
            batch["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), L.DTYPE)
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((b, AUDIO_ENC_LEN, cfg.d_model), L.DTYPE)
        return {"batch": batch}
    if shape.kind == "prefill":
        st = s - cfg.num_patches if cfg.has_vision_stub else s
        batch = {"tokens": _sds((b, st), jnp.int32)}
        if cfg.has_vision_stub:
            batch["patch_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), L.DTYPE)
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((b, AUDIO_ENC_LEN, cfg.d_model), L.DTYPE)
        return {"batch": batch}
    if shape.kind == "decode":
        caches = cache_specs(cfg, b, s)
        return {
            "token": _sds((b, 1), jnp.int32),
            "caches": caches,
            "cache_len": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    """Abstract KV/state caches for a decode step with context s_max.

    Derived via eval_shape of the prefill program so cache pytrees can never
    drift from what apply_prefill actually returns.
    """
    prefill_batch = {"tokens": _sds((batch, s_max), jnp.int32)}
    if cfg.has_vision_stub:
        prefill_batch = {
            "tokens": _sds((batch, s_max - cfg.num_patches), jnp.int32),
            "patch_embeds": _sds((batch, cfg.num_patches, cfg.d_model), L.DTYPE),
        }
    if cfg.is_encoder_decoder:
        prefill_batch["frames"] = _sds((batch, AUDIO_ENC_LEN, cfg.d_model), L.DTYPE)
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    _, caches = jax.eval_shape(lambda p, bt: apply_prefill(cfg, p, bt), params, prefill_batch)
    return caches


# ---------------------------------------------------------------------------
# analytic parameter / FLOP model (roofline §)
# ---------------------------------------------------------------------------


def count_params_analytic(cfg: ModelConfig, active_only=False):
    """Parameter count via abstract init (no allocation).

    active_only: routed-expert weights scaled by (top_k / num_experts) —
    the per-token active parameter count used for MoE MODEL_FLOPS.
    """
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        size = int(np.prod(leaf.shape))
        path_s = jax.tree_util.keystr(path)
        if active_only and "moe" in path_s and leaf.ndim == 4:
            # stacked routed experts [layers, E, ...]
            size = int(size * cfg.num_experts_per_tok / cfg.num_experts)
        total += size
    return total


def count_embedding_params(cfg: ModelConfig):
    return cfg.vocab_size * cfg.d_model * 2  # embed + lm_head


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Useful MODEL_FLOPS for one step (6*N*T train / 2*N*T inference
    + quadratic attention term). MoE uses active params."""
    n_active = count_params_analytic(cfg, active_only=True) - count_embedding_params(cfg)
    n_active += cfg.d_model * cfg.vocab_size  # lm_head matmul is real work
    b, s = shape.global_batch, shape.seq_len

    n_attn_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))
    hd = cfg.resolved_head_dim if not cfg.use_mla else (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    h = cfg.num_heads

    if shape.kind == "train":
        tok = b * s
        attn = 3 * 2 * 2 * b * (s * s / 2) * h * hd * n_attn_layers  # bwd x (QK^T + PV), causal
        return 6.0 * n_active * tok + attn
    if shape.kind == "prefill":
        tok = b * s
        attn = 2 * 2 * b * (s * s / 2) * h * hd * n_attn_layers
        return 2.0 * n_active * tok + attn
    # decode: one token against an s-long context
    attn = 2 * 2 * b * s * h * hd * n_attn_layers
    ssm_layers = sum(1 for i in range(cfg.num_layers) if not cfg.is_attn_layer(i)) if cfg.family in ("ssm", "hybrid") else 0
    ssm = 2 * b * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state_dim * ssm_layers * 3 if ssm_layers else 0
    return 2.0 * n_active * b + attn + ssm
