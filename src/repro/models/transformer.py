"""Generic decoder-only LM covering dense / GQA / MLA / MoE / SSM / hybrid.

A model is a sequence of *scan groups*. Each group is `count` identical scan
units; a unit is a short list of sublayer descriptors (mixer, ffn):

  dense/moe/vlm : [ (attn|mla, mlp|moe) ] x num_layers      (1 group, or 2 for
                   deepseek's first-k-dense prefix)
  ssm           : [ (mamba, none) ] x num_layers
  hybrid(jamba) : one unit = 8 sublayers  [m,m,m,m,a,m,m,m] with moe on odd
                   positions, scanned over num_layers/8 superblocks

Units are homogeneous within a group, so parameters stack on a leading axis
and `lax.scan` keeps the HLO size O(distinct unit structures), not O(layers) —
this is what keeps the 61-layer/512-device dry-run compiles tractable.
Training bodies are wrapped in jax.checkpoint (full per-unit remat).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import regather_params_tp
from repro.models import layers as L
from repro.models import mamba2, mla

# Scan groups with at most this many units are always fully unrolled under
# partial-unroll cost accounting (leaves exactly one while loop per model for
# the two-point extrapolation in launch/dryrun.py).
FULL_UNROLL_THRESHOLD = 8


def _resolve_unroll(unroll, n_units: int) -> int:
    if unroll in (-1, True) or n_units <= FULL_UNROLL_THRESHOLD:
        return n_units
    if unroll and unroll > 0:
        return min(int(unroll), n_units)
    return 1


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


def decoder_plan(cfg: ModelConfig):
    """[(count, [(mixer, ffn), ...]), ...] — scan groups for the decoder."""
    if cfg.family == "hybrid":
        period = cfg.attn_layer_period
        assert cfg.num_layers % period == 0
        descs = []
        for j in range(period):
            mixer = "attn" if j == cfg.attn_layer_offset else "mamba"
            ffn = "moe" if cfg.is_moe_layer(j) else "mlp"
            descs.append((mixer, ffn))
        return [(cfg.num_layers // period, descs)]
    if cfg.family == "ssm":
        return [(cfg.num_layers, [("mamba", "none")])]
    mixer = "mla" if cfg.use_mla else "attn"
    groups = []
    if cfg.first_k_dense:
        groups.append((cfg.first_k_dense, [(mixer, "mlp")]))
    ffn = "moe" if cfg.num_experts else "mlp"
    groups.append((cfg.num_layers - cfg.first_k_dense, [(mixer, ffn)]))
    return groups


# ---------------------------------------------------------------------------
# sublayers
# ---------------------------------------------------------------------------


def sublayer_init(key, cfg: ModelConfig, mixer: str, ffn: str, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": L.rms_norm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = L.attn_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
        )
    elif mixer == "mla":
        p["mla"] = mla.mla_init(ks[0], cfg)
    elif mixer == "mamba":
        p["mamba"] = mamba2.mamba2_init(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if cross:
        p["ln_cross"] = L.rms_norm_init(cfg.d_model)
        p["cross"] = L.attn_init(
            ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        )
    if ffn != "none":
        p["ln2"] = L.rms_norm_init(cfg.d_model)
        if ffn == "moe":
            p["moe"] = L.moe_init(
                ks[2], cfg.d_model, cfg.num_experts, cfg.moe_d_ff,
                num_shared=cfg.num_shared_experts, shared_d_ff=cfg.moe_d_ff,
            )
        else:
            p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _attn_kwargs(cfg: ModelConfig):
    return dict(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        theta=cfg.rope_theta,
    )


def _cross_kv(cfg, p, enc_out):
    """Per-layer cross-attention K/V from the encoder output."""
    b, se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = L.dense(p["wk"], enc_out).reshape(b, se, cfg.num_kv_heads, hd)
    v = L.dense(p["wv"], enc_out).reshape(b, se, cfg.num_kv_heads, hd)
    return {"k": k, "v": v}


def _cross_attention(cfg, p, x, kv):
    """Cross-attention over (cached) encoder K/V — bidirectional, no RoPE."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    mask = jnp.ones((1, 1, s, kv["k"].shape[1]), bool)
    out = L._sdpa(q, kv["k"], kv["v"], mask)
    return L.dense(p["wo"], out.reshape(b, s, cfg.num_heads * hd))


def sublayer_apply(cfg: ModelConfig, p, x, positions, mode, cache=None,
                   cache_len=None, enc_out=None, causal=True, cache_pad_to=0):
    """Returns (x, new_cache, aux).

    enc_out: encoder output for cross-attention sublayers (train/prefill);
    at decode the per-layer cross K/V come from the cache instead.
    """
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
    new_cache: dict[str, Any] = {}
    if "attn" in p:
        kw = _attn_kwargs(cfg)
        if mode == "train":
            a = L.attention(p["attn"], h, positions, causal=causal, **kw)
        elif mode == "prefill":
            a, c = L.attention_prefill(p["attn"], h, positions, cache_pad_to=cache_pad_to, **kw)
            new_cache["attn"] = c
        else:
            s_max = cache["attn"]["k"].shape[1]
            window = cfg.sliding_window if (cfg.sliding_window and s_max > 100_000) else 0
            a, c = L.attention_decode(p["attn"], h, cache["attn"], cache_len, window=window, **kw)
            new_cache["attn"] = c
    elif "mla" in p:
        if mode == "train":
            a = mla.mla_attention(p["mla"], h, positions, cfg)
        elif mode == "prefill":
            a, c = mla.mla_attention(p["mla"], h, positions, cfg, return_cache=True,
                                     cache_pad_to=cache_pad_to)
            new_cache["mla"] = c
        else:
            a, c = mla.mla_decode(p["mla"], h, cache["mla"], cache_len, cfg)
            new_cache["mla"] = c
    elif "mamba" in p:
        if mode == "train":
            a = mamba2.mamba2_forward(p["mamba"], h, cfg)
        elif mode == "prefill":
            a, c = mamba2.mamba2_forward(p["mamba"], h, cfg, return_cache=True)
            new_cache["mamba"] = c
        else:
            a, c = mamba2.mamba2_decode(p["mamba"], h, cache["mamba"], cfg)
            new_cache["mamba"] = c
    else:
        raise ValueError("sublayer has no mixer")
    x = x + a

    if "cross" in p:
        hc = L.rms_norm(p["ln_cross"], x, cfg.norm_eps)
        if mode == "decode":
            kv = cache["cross"]
        else:
            kv = _cross_kv(cfg, p["cross"], enc_out)
        if mode == "prefill":
            new_cache["cross"] = kv
        elif mode == "decode":
            new_cache["cross"] = kv
        x = x + _cross_attention(cfg, p["cross"], hc, kv)

    if "mlp" in p or "moe" in p:
        h2 = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            y, aux = L.moe(
                p["moe"], h2, num_experts=cfg.num_experts,
                top_k=cfg.num_experts_per_tok, capacity_factor=cfg.moe_capacity_factor,
            )
        else:
            y = L.mlp(p["mlp"], h2, cfg.act)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# scan groups
# ---------------------------------------------------------------------------


def group_init(key, cfg: ModelConfig, count: int, descs, cross: bool = False):
    """Stacked params: {"sub{j}": params stacked on axis 0 (count)}."""
    def unit_init(k):
        ks = jax.random.split(k, len(descs))
        return {f"sub{j}": sublayer_init(ks[j], cfg, m, f, cross=cross)
                for j, (m, f) in enumerate(descs)}

    keys = jax.random.split(key, count)
    return jax.vmap(unit_init)(keys)


def group_apply_train(cfg, group_params, descs, x, positions, enc_out=None, causal=True,
                      remat_policy="full", unroll=False, zero3_gather=False):
    def body(carry, unit_p):
        x, aux = carry
        if zero3_gather:
            unit_p = regather_params_tp(unit_p)
        for j in range(len(descs)):
            x, _, a = sublayer_apply(cfg, unit_p[f"sub{j}"], x, positions, "train",
                                     enc_out=enc_out, causal=causal)
            aux = aux + a
        return (x, aux), None

    if remat_policy == "full":
        body = jax.checkpoint(body)
    elif remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    elif remat_policy != "none":
        raise ValueError(remat_policy)
    n_units = jax.tree_util.tree_leaves(group_params)[0].shape[0]
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), group_params,
                               unroll=_resolve_unroll(unroll, n_units))
    return x, aux


def group_apply_prefill(cfg, group_params, descs, x, positions, enc_out=None,
                        cache_pad_to=0, unroll=False, zero3_gather=False):
    def body(x, unit_p):
        caches = {}
        if zero3_gather:
            unit_p = regather_params_tp(unit_p)
        for j in range(len(descs)):
            x, c, _ = sublayer_apply(cfg, unit_p[f"sub{j}"], x, positions, "prefill",
                                     enc_out=enc_out, cache_pad_to=cache_pad_to)
            caches[f"sub{j}"] = c
        return x, caches

    n_units = jax.tree_util.tree_leaves(group_params)[0].shape[0]
    x, caches = jax.lax.scan(body, x, group_params,
                             unroll=_resolve_unroll(unroll, n_units))
    return x, caches


def group_apply_decode(cfg, group_params, descs, x, caches, cache_len, unroll=False,
                       zero3_gather=False):
    def body(x, inp):
        unit_p, cache = inp
        new_caches = {}
        if zero3_gather:
            unit_p = regather_params_tp(unit_p)
        for j in range(len(descs)):
            x, c, _ = sublayer_apply(cfg, unit_p[f"sub{j}"], x, None, "decode",
                                     cache=cache[f"sub{j}"], cache_len=cache_len)
            new_caches[f"sub{j}"] = c
        return x, new_caches

    n_units = jax.tree_util.tree_leaves(group_params)[0].shape[0]
    x, new_caches = jax.lax.scan(body, x, (group_params, caches),
                                 unroll=_resolve_unroll(unroll, n_units))
    return x, new_caches
