"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b] — dense MHA decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100352, head_dim=64,
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-1.6b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
)
