"""Model configuration schema + registry for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    moe_layer_period: int = 1        # every k-th layer is MoE (jamba: 2)
    first_k_dense: int = 0           # deepseek-v3: first 3 layers dense
    moe_capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / jamba) ---
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    ssm_n_groups: int = 1
    attn_layer_period: int = 0       # hybrid: one attention layer per period
    attn_layer_offset: int = 0

    # --- encoder-decoder (seamless-m4t) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- modality stubs ---
    has_vision_stub: bool = False    # internvl2: precomputed patch embeds
    num_patches: int = 256
    has_audio_stub: bool = False     # seamless: precomputed frame embeds

    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0          # used by hybrid attn layers at 500k ctx
    act: str = "silu"                # mlp activation: silu (glu) | gelu (plain)

    # How many leading layers are materialized outside the scan.
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_layer_period:
            return i % self.attn_layer_period == self.attn_layer_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.num_experts:
            return False
        if i < self.first_k_dense:
            return False
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1) if self.moe_layer_period > 1 else True

    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing => long_500k shape runs."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        from repro.models.model_zoo import count_params_analytic

        return count_params_analytic(self)


_REGISTRY = {
    "qwen2-7b": "repro.configs.qwen2_7b",
    "granite-20b": "repro.configs.granite_20b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_REGISTRY)}")
    mod = importlib.import_module(_REGISTRY[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_REGISTRY[arch])
    return mod.SMOKE_CONFIG
