"""InternVL2-2B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B].

InternLM2-1.8B language backbone (24L, GQA kv=8). The InternViT vision
frontend is a STUB per the assignment: input_specs() provides precomputed
patch embeddings [batch, num_patches, d_model] that are prepended to the
token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    has_vision_stub=True, num_patches=256,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-2b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    has_vision_stub=True, num_patches=8,
)
