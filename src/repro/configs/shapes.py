"""Assigned input shapes (4 per architecture = 40 dry-run cells).

Shape kinds:
  train_4k    — training step, seq 4096, global batch 256
  prefill_32k — inference prefill, seq 32768, global batch 32
  decode_32k  — one-token decode against a 32768-token KV cache, batch 128
  long_500k   — one-token decode at 524288 context, batch 1; requires
                sub-quadratic sequence mixing (SSM/hybrid only — pure
                full-attention archs SKIP this cell, see DESIGN.md §5)
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = InputShape("train_4k", "train", 4096, 256)
PREFILL_32K = InputShape("prefill_32k", "prefill", 32768, 32)
DECODE_32K = InputShape("decode_32k", "decode", 32768, 128)
LONG_500K = InputShape("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig):
    """The shape cells this architecture runs (long_500k gated on family)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context():
        out.append(LONG_500K)
    return tuple(out)


def get_shape(name: str) -> InputShape:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")
