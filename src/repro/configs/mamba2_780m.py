"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state_dim=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    conv_kernel=4,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
    conv_kernel=4,
)
