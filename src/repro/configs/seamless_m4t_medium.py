"""SeamlessM4T-medium [arXiv:2308.11596; hf:facebook/seamless-m4t-medium].

Encoder-decoder transformer backbone (12L + 12L, d=1024, MHA, plain GELU
FFN). The speech frontend is a STUB: input_specs() provides precomputed
frame embeddings [batch, frames, d_model] for the encoder. Decoder performs
text generation over the 256206-entry vocabulary.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    is_encoder_decoder=True, num_encoder_layers=12,
    has_audio_stub=True, act="gelu",
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    is_encoder_decoder=True, num_encoder_layers=2,
    has_audio_stub=True, act="gelu",
)
