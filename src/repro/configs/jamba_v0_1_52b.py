"""Jamba-v0.1 (52B) [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1].

Mamba+attention 1:7 interleave (one attention layer per 8, at offset 4),
MoE (16 experts, top-2) on every second layer. DESIGN.md notes: mamba blocks
use our SSD implementation (d_state=16 per the paper); attention layers use a
4096-token sliding window for the long_500k shape.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    num_experts=16, num_experts_per_tok=2, moe_d_ff=14336, moe_layer_period=2,
    ssm_state_dim=16, ssm_head_dim=128, ssm_expand=2, ssm_chunk=256,
    conv_kernel=4,
    attn_layer_period=8, attn_layer_offset=4,
    sliding_window=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    num_experts=4, num_experts_per_tok=2, moe_d_ff=128, moe_layer_period=2,
    ssm_state_dim=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
    conv_kernel=4,
    attn_layer_period=8, attn_layer_offset=4,
    sliding_window=64,
)
