"""Qwen2-7B [arXiv:2407.10671; hf:Qwen/Qwen2-7B] — dense GQA decoder, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, qkv_bias=True,
)
