"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924] — 64e top-8 MoE."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    num_experts=64, num_experts_per_tok=8, moe_d_ff=1024,
)

# capacity_factor = E / top_k makes the smoke model *dropless* (capacity >=
# tokens): capacity drops depend on the whole batch, so a dropping forward is
# unreproducible by single-token decode and would break prefill/decode parity.
# The full config keeps the production factor (1.25) — drops are a throughput
# knob at scale, not part of smoke-scale semantics.
SMOKE_CONFIG = ModelConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=128,
    moe_capacity_factor=4.0,
)
