"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — qwen1.5-arch dense MHA, QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0, norm_eps=1e-6,
)

SMOKE_CONFIG = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16, qkv_bias=True,
)
