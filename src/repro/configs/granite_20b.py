"""Granite-20B-Code [arXiv:2405.04324; hf] — llama-arch MQA (kv=1) code model."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-20b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=512, head_dim=16,
)
