"""DeepSeek-V3 (671B) [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

MLA attention (q_lora 1536, kv_lora 512, qk 128+64 rope, v 128);
MoE: 1 shared + 256 routed experts, top-8, expert dim 2048; first 3 layers
dense with d_ff 18432. The MTP head is omitted (DESIGN.md §8).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    num_experts=256, num_experts_per_tok=8, num_shared_experts=1,
    moe_d_ff=2048, first_k_dense=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512,
    num_experts=8, num_experts_per_tok=2, num_shared_experts=1,
    moe_d_ff=32, first_k_dense=1,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
)
