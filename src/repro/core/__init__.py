"""Core: the paper's GPU LSM as a TPU-native, jit-compatible dictionary."""

from repro.core.lsm import (  # noqa: F401
    LSMConfig,
    LSMState,
    lsm_init,
    lsm_update,
    lsm_stage,
    lsm_flush,
    lsm_insert,
    lsm_delete,
    lsm_update_mixed,
    lsm_bulk_build,
    lsm_num_elements,
    lsm_debt,
    level_runs,
    level_view,
    buffer_run,
    all_runs,
    compact_real,
)
from repro.core.queries import (  # noqa: F401
    lsm_lookup,
    lsm_count,
    lsm_range,
    lookup_runs,
    count_runs,
    range_runs,
)
from repro.core.cleanup import lsm_cleanup, lsm_maintain, lsm_valid_count  # noqa: F401
