"""Sorted-array (SA) baseline (paper §5.1): one big sorted run.

Updates merge the (sorted) incoming batch into the whole array — O(n) work per
batch versus the LSM's O(b log r) — which is exactly the gap Table 2 / Fig. 2b
of the paper quantify. Queries reuse the shared run-based pipelines with a
single run, so query semantics (tombstones, recency) are identical.

Fixed-shape adaptation: a static-capacity arena padded with placebos. The
rank-based merge writes each merged position < capacity exactly once; placebo
overflow past the end is dropped. The caller must keep
live-elements + batch <= capacity (checked by `sa_would_overflow`).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import semantics as sem
from repro.core import queries
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SAConfig:
    capacity: int


class SAState(NamedTuple):
    key_vars: jnp.ndarray  # int32[capacity]
    values: jnp.ndarray    # int32[capacity]
    n: jnp.ndarray         # int32[] — resident elements (incl. stale, excl. placebo)


def sa_init(cfg: SAConfig) -> SAState:
    kv = jnp.full((cfg.capacity,), sem.PLACEBO_KV, dtype=jnp.int32)
    val = jnp.full((cfg.capacity,), sem.EMPTY_VALUE, dtype=jnp.int32)
    return SAState(kv, val, jnp.zeros((), jnp.int32))


def sa_bulk_build(cfg: SAConfig, keys, values) -> SAState:
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    if n > cfg.capacity:
        raise ValueError("bulk build exceeds capacity")
    kv, vals = ops.sort_pairs(sem.encode_insert(keys), values)
    pad = cfg.capacity - n
    kv = jnp.concatenate([kv, jnp.full((pad,), sem.PLACEBO_KV, jnp.int32)])
    vals = jnp.concatenate([vals, jnp.full((pad,), sem.EMPTY_VALUE, jnp.int32)])
    return SAState(kv, vals, jnp.asarray(n, jnp.int32))


def sa_update_batch(cfg: SAConfig, state: SAState, key_vars, values) -> SAState:
    """Merge a batch of encoded updates into the array (sort + full merge).

    In-batch duplicates follow the paper's rule: the full-key-variable sort
    puts a tombstone before any same-batch insert of its key."""
    bkv, bval = ops.sort_pairs(jnp.asarray(key_vars, jnp.int32), jnp.asarray(values, jnp.int32))
    return _sa_merge_sorted(cfg, state, bkv, bval)


def sa_stage(cfg: SAConfig, state: SAState, key_vars, values, count=None) -> SAState:
    """Apply one encoded sub-batch with the write-buffer recency rule.

    The SA has no staging buffer — applying immediately is equivalent to the
    LSM's buffer-then-flush because staged elements are queried as the newest
    run either way. What must match is the duplicate rule: the recency sort
    makes the later lane win (even a later insert over an earlier same-call
    tombstone), unlike `sa_update_batch`'s paper rule. `count` is unused —
    placebo lanes are invisible and excluded from the occupancy count."""
    del count
    bkv, bval = ops.sort_pairs_recency(
        jnp.asarray(key_vars, jnp.int32), jnp.asarray(values, jnp.int32)
    )
    return _sa_merge_sorted(cfg, state, bkv, bval)


def _sa_merge_sorted(cfg: SAConfig, state: SAState, bkv, bval) -> SAState:
    b = bkv.shape[0]
    a_keys = sem.original_key(bkv)          # batch = newer run
    c_keys = sem.original_key(state.key_vars)
    idx_a = jnp.arange(b, dtype=jnp.int32) + jnp.searchsorted(c_keys, a_keys, side="left").astype(jnp.int32)
    idx_c = jnp.arange(cfg.capacity, dtype=jnp.int32) + jnp.searchsorted(a_keys, c_keys, side="right").astype(jnp.int32)
    out_kv = jnp.full((cfg.capacity,), sem.PLACEBO_KV, dtype=jnp.int32)
    out_val = jnp.full((cfg.capacity,), sem.EMPTY_VALUE, dtype=jnp.int32)
    # Positions >= capacity are placebo overflow — dropped. Live elements can
    # only be dropped if the caller violated the capacity precondition.
    out_kv = out_kv.at[idx_a].set(bkv, mode="drop").at[idx_c].set(state.key_vars, mode="drop")
    out_val = out_val.at[idx_a].set(bval, mode="drop").at[idx_c].set(state.values, mode="drop")
    # Placebo padding lanes (facade partial batches) are not resident elements.
    real = jnp.sum(bkv != sem.PLACEBO_KV).astype(jnp.int32)
    return SAState(out_kv, out_val, state.n + real)


def sa_insert(cfg: SAConfig, state: SAState, keys, values) -> SAState:
    return sa_update_batch(cfg, state, sem.encode_insert(keys), values)


def sa_delete(cfg: SAConfig, state: SAState, keys) -> SAState:
    kv = sem.encode_delete(keys)
    vals = jnp.full((kv.shape[0],), sem.EMPTY_VALUE, dtype=jnp.int32)
    return sa_update_batch(cfg, state, kv, vals)


def sa_would_overflow(cfg: SAConfig, state: SAState, batch: int):
    return state.n + batch > cfg.capacity


def sa_cleanup(cfg: SAConfig, state: SAState) -> SAState:
    """Purge stale elements (older duplicates, tombstones): the single-run
    analogue of the LSM's CLEANUP — survivors compact to the front, the tail
    refills with placebos."""
    survives = queries.survivor_mask(state.key_vars)
    total = jnp.sum(survives).astype(jnp.int32)
    tgt = jnp.cumsum(survives) - 1
    tgt = jnp.where(survives, tgt, cfg.capacity)  # out-of-range -> dropped
    out_kv = jnp.full((cfg.capacity,), sem.PLACEBO_KV, dtype=jnp.int32)
    out_val = jnp.full((cfg.capacity,), sem.EMPTY_VALUE, dtype=jnp.int32)
    out_kv = out_kv.at[tgt].set(state.key_vars, mode="drop")
    out_val = out_val.at[tgt].set(state.values, mode="drop")
    return SAState(out_kv, out_val, total)


def _runs(state: SAState):
    return [(state.key_vars, state.values)]


def sa_lookup(cfg: SAConfig, state: SAState, query_keys):
    return queries.lookup_runs(_runs(state), query_keys)


def sa_count(cfg: SAConfig, state: SAState, k1, k2, max_candidates: int):
    return queries.count_runs(_runs(state), k1, k2, max_candidates)


def sa_range(cfg: SAConfig, state: SAState, k1, k2, max_candidates: int, max_results: int):
    return queries.range_runs(_runs(state), k1, k2, max_candidates, max_results)
