"""Key-variable encoding for the TPU LSM (paper §4.1).

The paper stores 32-bit "key variables": the 31-bit *original key* shifted left
once, with the LSB used as a *status bit* (1 = regular element, 0 = tombstone).
Sorting uses the full key variable, so within one sorted batch a tombstone for
key k appears *before* any regular element with key k (invariant 2 of §3.4).
Merging compares original keys only and is stable with the newer array first
(invariants 1 and 3).

We keep the exact encoding in int32. Because int32 is signed and original keys
occupy bits [1, 31], encoded key variables of valid original keys are
non-negative, so signed comparisons order exactly like the paper's unsigned
ones for the supported key domain [0, 2**30 - 1] plus the placebo key.

Empty slots in the fixed-capacity arena are *placebo* elements (paper §4.5,
footnote 6): maximum original key + tombstone status. They sort to the end of
every level and are invisible to all queries.
"""

from __future__ import annotations

import jax.numpy as jnp

# Original keys live in [0, MAX_KEY]. MAX_KEY itself is reserved for placebos.
# We use 2**30 - 1 as the largest user key so that (key << 1) stays positive
# in int32 even with the status bit set.
PLACEBO_KEY = (1 << 30) - 1          # reserved original key for padding
MAX_USER_KEY = PLACEBO_KEY - 1       # largest insertable original key

STATUS_REGULAR = 1
STATUS_TOMBSTONE = 0

# Encoded placebo key-variable: placebo original key, tombstone status.
PLACEBO_KV = (PLACEBO_KEY << 1) | STATUS_TOMBSTONE

# Sentinel "value" stored alongside placebos / tombstones.
EMPTY_VALUE = 0


def encode(keys, is_tombstone):
    """Pack original keys + status bits into key variables.

    is_tombstone: bool array — True marks a deletion (tombstone).
    """
    keys = jnp.asarray(keys, dtype=jnp.int32)
    status = jnp.where(jnp.asarray(is_tombstone), STATUS_TOMBSTONE, STATUS_REGULAR)
    return (keys << 1) | status.astype(jnp.int32)


def encode_insert(keys):
    keys = jnp.asarray(keys, dtype=jnp.int32)
    return (keys << 1) | STATUS_REGULAR


def encode_delete(keys):
    keys = jnp.asarray(keys, dtype=jnp.int32)
    return (keys << 1) | STATUS_TOMBSTONE


def original_key(key_vars):
    """Strip the status bit (logical shift — key vars are non-negative)."""
    return jnp.asarray(key_vars, dtype=jnp.int32) >> 1


def status_bit(key_vars):
    return jnp.asarray(key_vars, dtype=jnp.int32) & 1


def is_tombstone(key_vars):
    return status_bit(key_vars) == STATUS_TOMBSTONE


def is_placebo(key_vars):
    return original_key(key_vars) == PLACEBO_KEY
