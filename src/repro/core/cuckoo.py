"""Static cuckoo hash table baseline (paper §5.1; Alcantara et al. 2009).

Bulk-synchronous parallel build in the style of the CUDPP GPU cuckoo table the
paper benchmarks against: every unplaced key claims a slot for its current
hash choice; the winner per slot is resolved with a deterministic scatter-max
(the TPU-safe stand-in for CUDA atomicMax); losers — and evicted previous
occupants — advance to their next of 4 hash functions and retry next round.

The loop state is a single slot->key-id ownership table, so each round is
O(n + m) scatters/gathers; keys/values are materialized from the ownership
table once after the loop.

Like the paper's baseline it is immutable once built, has O(1) lookups, and
cannot answer ordered (count/range) queries — which is the entire point of the
comparison in Table 1.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)
_NUM_HASHES = 4
_HASH_A = (2654435761, 2246822519, 3266489917, 668265263)
_HASH_C = (374761393, 3242174893, 1540483477, 2654435769)


@dataclasses.dataclass(frozen=True)
class CuckooConfig:
    table_size: int          # number of slots (n / load_factor)
    max_rounds: int = 64
    seed: int = 0            # hash-family seed; bump and rebuild on failure


class CuckooTable(NamedTuple):
    slot_keys: jnp.ndarray   # int32[table_size], EMPTY where unoccupied
    slot_vals: jnp.ndarray   # int32[table_size]
    build_ok: jnp.ndarray    # bool[] — every key placed


def _hash(cfg: CuckooConfig, keys, which):
    """which: int32 array selecting one of the 4 hash functions per key."""
    k = keys.astype(jnp.uint32) ^ jnp.uint32(cfg.seed * 0x85EBCA6B)
    h = jnp.zeros_like(k)
    for i in range(_NUM_HASHES):
        hi = (k * jnp.uint32(_HASH_A[i]) + jnp.uint32(_HASH_C[i]))
        hi = (hi ^ (hi >> 15)) % jnp.uint32(cfg.table_size)
        h = jnp.where(which == i, hi, h)
    return h.astype(jnp.int32)


def cuckoo_build(cfg: CuckooConfig, keys, values) -> CuckooTable:
    """Bulk build. Keys must be unique and non-negative."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    m = cfg.table_size
    ids = jnp.arange(n, dtype=jnp.int32)

    all_h = [_hash(cfg, keys, jnp.full((n,), j, jnp.int32)) for j in range(_NUM_HASHES)]

    def _recompute_placed(slot_owner):
        # A key is placed iff it survives in one of its 4 candidate slots —
        # evictions are discovered here rather than tracked explicitly
        # (self-healing; mirrors the CUDPP retry loop).
        placed = jnp.zeros((n,), dtype=bool)
        for hj in all_h:
            placed = placed | (slot_owner[hj] == ids)
        return placed

    def round_body(state):
        slot_owner, attempt, placed, it = state
        h = _hash(cfg, keys, attempt % _NUM_HASHES)
        # Claim contested slots: the winner is a deterministic scatter-max
        # over a round-permuted id, so the victor varies between rounds — the
        # bulk-synchronous analogue of random-walk cuckoo eviction (fixed
        # priorities lockstep into A-evicts-B-evicts-A cycles).
        tid = ids ^ ((it * jnp.int32(0x9E3779B)) & jnp.int32(0x3FFFFFFF))
        claims = jnp.full((m,), EMPTY, dtype=jnp.int32)
        claims = claims.at[h].max(jnp.where(placed, EMPTY, tid))
        won = (~placed) & (claims[h] == tid)
        slot_owner = slot_owner.at[jnp.where(won, h, m)].set(ids, mode="drop")
        placed = _recompute_placed(slot_owner)
        attempt = jnp.where(~placed, attempt + 1, attempt)
        return slot_owner, attempt, placed, it + 1

    def cond(state):
        _, _, placed, it = state
        return (~jnp.all(placed)) & (it < cfg.max_rounds)

    slot_owner = jnp.full((m,), EMPTY, dtype=jnp.int32)
    attempt = jnp.zeros((n,), dtype=jnp.int32)
    placed = jnp.zeros((n,), dtype=bool)
    slot_owner, attempt, placed, _ = jax.lax.while_loop(
        cond, round_body, (slot_owner, attempt, placed, jnp.int32(0))
    )
    occupied = slot_owner >= 0
    owner_c = jnp.clip(slot_owner, 0, n - 1)
    slot_keys = jnp.where(occupied, keys[owner_c], EMPTY)
    slot_vals = jnp.where(occupied, values[owner_c], 0)
    return CuckooTable(slot_keys, slot_vals, jnp.all(placed))


def cuckoo_lookup(cfg: CuckooConfig, table: CuckooTable, query_keys):
    """Probe all 4 slots per query. Returns (found, values)."""
    q = jnp.asarray(query_keys, jnp.int32)
    found = jnp.zeros(q.shape, dtype=bool)
    vals = jnp.zeros(q.shape, dtype=jnp.int32)
    for i in range(_NUM_HASHES):
        h = _hash(cfg, q, jnp.full(q.shape, i, jnp.int32))
        hit = table.slot_keys[h] == q
        vals = jnp.where(hit & ~found, table.slot_vals[h], vals)
        found = found | hit
    return found, vals
