"""Binary-counter cascade engine — the shared core of every LSM mutation.

`lsm_update`, `lsm_stage`, `lsm_flush` (all pushing a carry batch through the
binary counter), `lsm_bulk_build`, `lsm_cleanup`, and `lsm_maintain` (all
re-slicing a sorted survivor prefix into levels) previously each carried their
own copy of the merge/scatter/redistribute machinery. This module is the
single implementation:

  * `push_batch` — one binary-counter increment. Where the old `_cascade`
    walked the levels with a chain of pairwise merges (each `lax.cond` step
    either merging or COPYING the carry past a non-participating level, so
    every update paid an O(b * 2^L) carry round trip regardless of where it
    landed), `push_batch` computes the placement level j = lowest zero bit of
    r up front and dispatches ONE `lax.switch` branch that performs a single
    fused K-way merge of [carry, level 0..j-1] (`ops.merge_cascade`). The
    executed program is O(b * 2^j) — the paper's amortized O(b log r) bound
    now holds per-branch, not just amortized over the cond chain.
  * `compact_run` — survivor scatter into a placebo-prefilled buffer.
  * `redistribute` — slice a sorted, unique-key prefix into levels by the
    bits of the new resident count (generalized to a level prefix, which is
    what budgeted maintenance compacts).
  * `run_stale_count` — per-run compaction-debt measurement, taken on the
    merged run a cascade step just produced (the only moment cross-batch
    staleness inside that run is visible for free).

This module deliberately imports only `semantics` and `kernels.ops`, so
`core/lsm.py` can import it at module level without a cycle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.kernels import ops


def _placebo(n):
    return (
        jnp.full((n,), sem.PLACEBO_KV, dtype=jnp.int32),
        jnp.full((n,), sem.EMPTY_VALUE, dtype=jnp.int32),
    )


def placement_level(r):
    """Index of the lowest zero bit of r — the level a carry batch lands in.

    The binary-counter increment r -> r+1 clears exactly the trailing-ones
    block and sets the bit above it; that bit's level receives the merge of
    the carry with all the cleared (full) levels below.
    """
    r = jnp.asarray(r, jnp.int32)
    lowest_zero = (~r) & (r + 1)  # power of two
    return jax.lax.population_count(lowest_zero - 1).astype(jnp.int32)


def run_stale_count(run_kv):
    """Resident real elements of one sorted run that a compaction of that run
    alone could reclaim: duplicates shadowed within the run plus tombstones.

    This is the per-level compaction-debt measurement. It is an ESTIMATE of
    what maintenance will actually reclaim — tombstones must be retained while
    older levels still hold data, and duplicate pairs split across two
    not-yet-merged levels are invisible until a merge brings them into one
    run — but it is exact for the run in isolation, costs one mask sum on an
    array that was just materialized anyway, and queries never depend on it
    (docs/DESIGN.md §11).
    """
    from repro.core.queries import survivor_mask

    real = jnp.sum(sem.original_key(run_kv) != sem.PLACEBO_KEY).astype(jnp.int32)
    return real - jnp.sum(survivor_mask(run_kv)).astype(jnp.int32)


def push_batch(cfg, state, carry_kv, carry_val):
    """Push one pre-sorted b-wide batch through the binary-counter cascade.

    The carry must be ascending in original key with the newest element first
    within every equal-key segment (the run invariant every query assumes).
    Both batch-formation rules feed this: `lsm_update` sorts by full key
    variable (paper §4.1 — tombstone-first within a batch) and the write
    buffer sorts by arrival sequence (docs/DESIGN.md §5 — newest-first).

    Placement level j = lowest zero bit of r; levels 0..j-1 are full by
    construction, and [carry, level 0..j-1] (newest first) K-way merge into
    level j, which sizes exactly to b * 2^j. Levels above j pass through
    untouched (buffer donation forwards them), as do the write-buffer fields.
    On overflow (r == max_batches) the state is preserved and the latch set.
    """
    num_levels = cfg.num_levels
    would_overflow = state.r >= cfg.max_batches
    branch_idx = jnp.where(
        would_overflow, jnp.int32(num_levels), placement_level(state.r)
    )

    def make_branch(j):
        def branch(kvs, vals, debt, ckv, cval):
            merged_kv, merged_val = ops.merge_cascade(
                [(ckv, cval)] + [(kvs[i], vals[i]) for i in range(j)]
            )
            new_kvs, new_vals = [], []
            for i in range(num_levels):
                if i < j:
                    pk, pv = _placebo(cfg.level_size(i))
                    new_kvs.append(pk)
                    new_vals.append(pv)
                elif i == j:
                    new_kvs.append(merged_kv)
                    new_vals.append(merged_val)
                else:
                    new_kvs.append(kvs[i])
                    new_vals.append(vals[i])
            new_debt = jnp.concatenate(
                [
                    jnp.zeros((j,), jnp.int32),
                    run_stale_count(merged_kv)[None],
                    debt[j + 1 :],
                ]
            )
            return tuple(new_kvs), tuple(new_vals), new_debt

        return branch

    def overflow_branch(kvs, vals, debt, ckv, cval):
        return tuple(kvs), tuple(vals), debt

    branches = [make_branch(j) for j in range(num_levels)] + [overflow_branch]
    new_kvs, new_vals, new_debt = jax.lax.switch(
        branch_idx,
        branches,
        state.key_vars,
        state.values,
        state.lvl_debt,
        carry_kv,
        carry_val,
    )
    return state._replace(
        key_vars=new_kvs,
        values=new_vals,
        lvl_debt=new_debt,
        r=jnp.where(would_overflow, state.r, state.r + 1),
        overflowed=state.overflowed | would_overflow,
    )


def compact_run(merged_kv, merged_val, keep, out_size: int):
    """Scatter the keep-masked elements of a sorted run to the front of a
    placebo-prefilled buffer of length `out_size` (order preserved — the
    prefill IS the paper's "pad with placebo elements" step).

    Returns (kv, val, total) where total is the UNCLAMPED survivor count;
    survivors past out_size (and non-survivors) scatter out of range and are
    dropped, so the caller decides whether an overflow latches.
    """
    total = jnp.sum(keep).astype(jnp.int32)
    tgt = jnp.cumsum(keep) - 1
    tgt = jnp.where(keep & (tgt < out_size), tgt, out_size)
    kv, val = _placebo(out_size)
    kv = kv.at[tgt].set(merged_kv, mode="drop")
    val = val.at[tgt].set(merged_val, mode="drop")
    return kv, val, total


def redistribute(cfg, compact_kv, compact_val, r_new, hi_level: int | None = None):
    """Slice a globally sorted, unique-key array into levels 0..hi_level.

    Level i (if bit i of r_new is set) receives the contiguous slice starting
    at b * (r_new & (2**i - 1)) — smallest keys land in the smallest levels
    (paper §4.5). With hi_level < num_levels - 1 this re-slices just a level
    PREFIX, which is exactly what budgeted maintenance rebuilds; full cleanup
    and bulk build use the default (all levels).

    Returns (kvs, vals) as tuples of length hi_level + 1.
    """
    if hi_level is None:
        hi_level = cfg.num_levels - 1
    b = cfg.batch_size
    kvs, vals = [], []
    for i in range(hi_level + 1):
        n = cfg.level_size(i)
        bit = ((r_new >> i) & 1) == 1
        src_start = b * (r_new & ((1 << i) - 1))
        sl_kv = jax.lax.dynamic_slice(compact_kv, (src_start,), (n,))
        sl_val = jax.lax.dynamic_slice(compact_val, (src_start,), (n,))
        pk, pv = _placebo(n)
        kvs.append(jnp.where(bit, sl_kv, pk))
        vals.append(jnp.where(bit, sl_val, pv))
    return tuple(kvs), tuple(vals)
