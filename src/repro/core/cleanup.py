"""CLEANUP (paper §3.6 / §4.5) and budgeted incremental maintenance.

The paper's CLEANUP is stop-the-world: merge everything, drop stale elements,
re-slice the levels. That rebuild is O(capacity) no matter how little debt the
structure carries, which shows up as a latency spike in any serving loop
(LUDA's observation — compactions belong off the hot path, amortized into
bounded slices). Both operations here are built on the shared cascade engine
(core/cascade.py):

  * `lsm_cleanup(cfg, state)` — the full rebuild, unchanged contract:
      1. ONE fused K-way merge of the write buffer (newest) and every level
         (`ops.merge_cascade` — previously a pairwise chain);
      2. survivor mask: first of each equal-key segment, regular, not placebo;
      3. compact survivors into a placebo-prefilled arena (`compact_run` —
         the prefill IS the paper's "pad with < b placebos" step);
      4. re-slice by the bits of the new resident count (`redistribute`).
    Folding the buffer into the merge empties it without burning a batch
    slot; because the buffer adds up to b elements beyond the level arenas,
    survivors can exceed capacity — the excess (largest keys) is dropped and
    the overflow latch set, same contract as an overflowing update.

  * `lsm_maintain(cfg, state, budget)` — incremental compaction bounded by a
    STATIC element budget per call. It compacts the deepest level PREFIX
    0..j whose total arena fits the budget (b * (2^(j+1) - 1) <= budget),
    with one fused merge + compact + prefix re-slice; levels above j and the
    write buffer are untouched. Correctness of the partial view:
      - within the prefix, only the newest element of each key survives —
        dropping older shadowed duplicates can never change a query, because
        every query already resolves to the newest match;
      - tombstones are PURGED only when no deeper level holds residents
        ((r >> (j+1)) == 0); otherwise they must survive to keep shadowing
        older elements below the compaction horizon;
      - prefix survivors stay newer than the untouched deeper levels, and
        keys are unique within the prefix, so the re-sliced levels satisfy
        the run invariant with no recency ambiguity.
    Survivors never exceed the prefix arena (no buffer is folded in), so
    maintenance can never overflow. `budget=None` (or >= capacity + b, i.e.
    enough for everything including the buffer) degrades to full
    `lsm_cleanup` — maintain(∞) IS cleanup. A budget below b is a no-op.

    The resident-batch counter keeps its high bits: r' = (r & ~mask) | ceil(
    survivors / b) with mask = 2^(j+1) - 1 — the binary counter simply shows
    fewer resident batches in the compacted prefix.

Maintenance debt is tracked per level in `LSMState.lvl_debt` (see
cascade.run_stale_count); `only_if_debt=True` gates the work behind a traced
prefix-debt check so piggybacked maintenance (facade update/flush paths) costs
one comparison when there is provably nothing to reclaim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cascade
from repro.core import semantics as sem
from repro.core.lsm import (
    LSMConfig,
    LSMState,
    _fresh_buffer,
    buffer_run,
    level_view,
)
from repro.kernels import ops


def merge_all_levels(cfg: LSMConfig, state: LSMState):
    """Stable newest-first merge of every level into one sorted run."""
    return ops.merge_cascade(
        [level_view(cfg, state, i) for i in range(cfg.num_levels)]
    )


def lsm_cleanup(cfg: LSMConfig, state: LSMState) -> LSMState:
    from repro.core.queries import survivor_mask

    b = cfg.batch_size
    runs = [buffer_run(cfg, state)] + [
        level_view(cfg, state, i) for i in range(cfg.num_levels)
    ]
    merged_kv, merged_val = ops.merge_cascade(runs)
    survives = survivor_mask(merged_kv)
    compact_kv, compact_val, total = cascade.compact_run(
        merged_kv, merged_val, survives, cfg.capacity
    )
    overflow = total > cfg.capacity
    total_kept = jnp.minimum(total, cfg.capacity)
    r_new = ((total_kept + b - 1) // b).astype(jnp.int32)
    kvs, vals = cascade.redistribute(cfg, compact_kv, compact_val, r_new)
    return LSMState(
        key_vars=kvs,
        values=vals,
        r=r_new,
        overflowed=state.overflowed | overflow,
        lvl_debt=jnp.zeros((cfg.num_levels,), dtype=jnp.int32),
        **_fresh_buffer(b),
    )


def maintain_prefix_level(cfg: LSMConfig, budget: int) -> int:
    """Deepest level j whose prefix arena 0..j fits the budget
    (b * (2^(j+1) - 1) <= budget); -1 when even level 0 does not fit."""
    j = -1
    for i in range(cfg.num_levels):
        if cfg.batch_size * ((1 << (i + 1)) - 1) <= budget:
            j = i
    return j


def _compact_prefix(cfg: LSMConfig, state: LSMState, j: int) -> LSMState:
    b = cfg.batch_size
    prefix_n = b * ((1 << (j + 1)) - 1)
    merged_kv, merged_val = ops.merge_cascade(
        [level_view(cfg, state, i) for i in range(j + 1)]
    )
    orig = sem.original_key(merged_kv)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), orig[:-1]])
    newest_per_key = (orig != prev) & (orig != sem.PLACEBO_KEY)
    # Tombstones may only be purged when nothing older exists below the
    # compaction horizon — otherwise they still shadow deeper elements. The
    # write buffer is NEWER than the prefix, so it never constrains this.
    covers_all = (state.r >> (j + 1)) == 0
    keep = jnp.where(
        covers_all, newest_per_key & ~sem.is_tombstone(merged_kv), newest_per_key
    )
    compact_kv, compact_val, total = cascade.compact_run(
        merged_kv, merged_val, keep, prefix_n
    )
    # total <= prefix_n by construction: at most one survivor per prefix key.
    r_prefix = ((total + b - 1) // b).astype(jnp.int32)
    kvs, vals = cascade.redistribute(cfg, compact_kv, compact_val, r_prefix, hi_level=j)
    mask = (1 << (j + 1)) - 1
    return state._replace(
        key_vars=kvs + state.key_vars[j + 1 :],
        values=vals + state.values[j + 1 :],
        r=(state.r & ~mask) | r_prefix,
        # Prefix debt resets; retained tombstones re-enter the estimate the
        # next time a cascade merge re-materializes these levels.
        lvl_debt=jnp.concatenate(
            [jnp.zeros((j + 1,), jnp.int32), state.lvl_debt[j + 1 :]]
        ),
    )


def lsm_maintain(
    cfg: LSMConfig,
    state: LSMState,
    budget: int | None = None,
    *,
    only_if_debt: bool = False,
) -> LSMState:
    """Budgeted incremental compaction: touch at most `budget` elements.

    budget is STATIC (a Python int or None). None — or any budget large
    enough for the whole structure plus the write buffer — performs a full
    `lsm_cleanup`. Otherwise the deepest affordable level prefix is compacted
    (see module docstring); a budget below b is a no-op. Queries are exact at
    every point of this spectrum — maintenance is observationally invisible,
    which the differential harness checks by interleaving random maintain
    ops into oracle-replayed sequences.

    only_if_debt=True skips the compaction (traced lax.cond) when the
    tracked prefix debt is zero — the cheap gate for piggybacked maintenance
    on facade update/flush paths.
    """
    if budget is None or budget >= cfg.capacity + cfg.batch_size:
        return lsm_cleanup(cfg, state)
    j = maintain_prefix_level(cfg, budget)
    if j < 0:
        return state
    if only_if_debt:
        return jax.lax.cond(
            jnp.sum(state.lvl_debt[: j + 1]) > 0,
            lambda st: _compact_prefix(cfg, st, j),
            lambda st: st,
            state,
        )
    return _compact_prefix(cfg, state, j)


def lsm_valid_count(cfg: LSMConfig, state: LSMState):
    """Number of live (visible) elements — what cleanup would retain
    (write-buffer residents included)."""
    from repro.core.queries import valid_count_runs
    from repro.core.lsm import all_runs

    return valid_count_runs(all_runs(cfg, state))
