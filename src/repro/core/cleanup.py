"""CLEANUP (paper §3.6 / §4.5): purge stale elements and re-slice the levels.

Strategy (all fixed-shape, one jitted program):
  1. stable-merge the write buffer (newest) and all levels newest-first —
     merging already-sorted runs is much cheaper than a full resort (§4.5);
  2. mark stale elements: an element survives iff it is the *first* (most
     recent) element of its equal-key segment, is a regular element (not a
     tombstone), and is not a placebo;
  3. compact survivors to the front (prefix-sum scatter);
  4. the compaction buffer is pre-filled with placebos — this IS the paper's
     "pad with < b placebo elements" step;
  5. redistribute the sorted, deduplicated prefix into levels according to the
     bits of the new resident-batch count (smallest keys → smallest levels).

Folding the buffer into the merge (instead of flushing it first) is the
cleanup-boundary flush the write-buffer design calls for: it empties the
buffer without placebo-padding a partial batch, so cleanup never wastes a
slot. Because the buffer can hold up to b elements beyond the level arenas,
survivors can exceed the static capacity; the excess (largest keys) is
dropped and the overflow latch set — same contract as an overflowing update.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.lsm import (
    LSMConfig,
    LSMState,
    _fresh_buffer,
    _placebo,
    _redistribute,
    buffer_run,
    level_view,
)
from repro.kernels import ops


def merge_all_levels(cfg: LSMConfig, state: LSMState):
    """Stable newest-first merge of every level into one sorted run."""
    merged_kv, merged_val = level_view(cfg, state, 0)
    for i in range(1, cfg.num_levels):
        lvl_kv, lvl_val = level_view(cfg, state, i)
        # Everything accumulated so far came from levels 0..i-1, all newer
        # than level i, so the accumulated run is the `a` (newer) argument.
        merged_kv, merged_val = ops.merge_sorted(merged_kv, merged_val, lvl_kv, lvl_val)
    return merged_kv, merged_val


def lsm_cleanup(cfg: LSMConfig, state: LSMState) -> LSMState:
    from repro.core.queries import survivor_mask

    b = cfg.batch_size
    buf_kv, buf_val = buffer_run(cfg, state)  # newest run, sorted
    merged_kv, merged_val = merge_all_levels(cfg, state)
    merged_kv, merged_val = ops.merge_sorted(buf_kv, buf_val, merged_kv, merged_val)
    survives = survivor_mask(merged_kv)

    total = jnp.sum(survives).astype(jnp.int32)
    overflow = total > cfg.capacity
    tgt = jnp.cumsum(survives) - 1
    # Survivors past capacity (possible only via a near-full buffer) and
    # non-survivors scatter out of range and are dropped.
    tgt = jnp.where(survives & (tgt < cfg.capacity), tgt, cfg.capacity)
    compact_kv, compact_val = _placebo(cfg.capacity)
    compact_kv = compact_kv.at[tgt].set(merged_kv, mode="drop")
    compact_val = compact_val.at[tgt].set(merged_val, mode="drop")

    total_kept = jnp.minimum(total, cfg.capacity)
    r_new = ((total_kept + b - 1) // b).astype(jnp.int32)
    kvs, vals = _redistribute(cfg, compact_kv, compact_val, r_new)
    return LSMState(
        key_vars=kvs,
        values=vals,
        r=r_new,
        overflowed=state.overflowed | overflow,
        **_fresh_buffer(b),
    )


def lsm_valid_count(cfg: LSMConfig, state: LSMState):
    """Number of live (visible) elements — what cleanup would retain
    (write-buffer residents included)."""
    from repro.core.queries import valid_count_runs
    from repro.core.lsm import all_runs

    return valid_count_runs(all_runs(cfg, state))
