"""TPU LSM: the paper's data structure as a fixed-shape, jit-native JAX module.

Layout (per-level arrays)
-------------------------
A GPU allocates levels lazily; a jit/pjit program needs static shapes. We
preallocate `num_levels` exponentially sized levels as separate arrays —
level i holds exactly b * 2**i slots. Keeping levels as distinct buffers (not
one flat arena) matters for the complexity story: a batch update rewrites
ONLY the levels the binary-counter carry touches (lax.switch pass-through +
buffer donation forwards untouched levels), preserving the paper's
O(b log r) amortized insertion cost. A flat arena would force an O(capacity)
rewrite per batch.

Empty levels (and the tails of cleaned-up levels) hold *placebo* elements —
maximum original key + tombstone status (paper §4.5 fn. 6) — which sort last
and are invisible to every query. "Empty" and "full" levels are therefore
indistinguishable to query code: no control flow depends on occupancy.

The resident-batch counter `r` mirrors the paper exactly: level i is full iff
bit i of r is set, and a batch update is a binary-counter increment whose
carries are stable merges.

Write buffer ("level −1")
-------------------------
The paper's update path is rigidly b-wide; real workloads trickle in ragged
sub-batches. A b-slot staging buffer in front of the merge cascade (the
canonical LSM memtable, docs/DESIGN.md §5) absorbs encoded sub-batch updates
in arrival order without consuming a batch slot: `lsm_stage` appends up to b
encoded lanes, and only when more than b elements are pending does it flush
the *oldest* b through the binary-counter cascade, retaining the newest
remainder. The buffer is queried as the newest run (see `all_runs`) and its
recency rule is strictly sequence-ordered: a later lane/call beats an earlier
one even across the insert/tombstone status boundary — unlike the paper's
in-batch rule where a tombstone beats any same-batch insert of its key.
`buf_seq` records the arrival rank explicitly (invariant: seq == buffer
position; placebo lanes hold b), `buf_n` the occupancy.

Everything here is traceable: `LSMConfig` is static (hashable) and `LSMState`
is a pytree, so `jax.jit(lsm_update, static_argnums=0, donate_argnums=1)`
works, as does sharding each level with pjit/shard_map (core/distributed.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.core import cascade
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LSMConfig:
    """Static configuration: batch size b and level count L (capacity b*(2^L-1))."""

    batch_size: int
    num_levels: int

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.num_levels < 1:
            raise ValueError("num_levels must be >= 1")

    @property
    def capacity(self) -> int:
        return self.batch_size * ((1 << self.num_levels) - 1)

    @property
    def max_batches(self) -> int:
        return (1 << self.num_levels) - 1

    def level_size(self, i: int) -> int:
        return self.batch_size * (1 << i)


class LSMState(NamedTuple):
    """Pytree state: per-level (key_var, value) arrays + counter + overflow
    latch + the write buffer ("level −1", docs/DESIGN.md §5)."""

    key_vars: Tuple[jax.Array, ...]  # level i: int32[b * 2**i]
    values: Tuple[jax.Array, ...]
    r: jax.Array                     # int32[] — number of resident batches
    overflowed: jax.Array            # bool[] — latches if an update overflowed
    buf_kv: jax.Array                # int32[b] — staged lanes, arrival order
    buf_val: jax.Array               # int32[b]
    # Explicit arrival-order witness (== position; b on placebo lanes).
    # Derivable from buf_n, but kept deliberately: it is the recency
    # authority the streaming design names, and variants that reorder the
    # raw buffer (e.g. a sorted-in-place memtable) would need the slot.
    # test_buffer_state_invariants pins it.
    buf_seq: jax.Array               # int32[b]
    buf_n: jax.Array                 # int32[] — buffer occupancy
    # Cached recency-sorted view of the buffer (ascending original key,
    # newest-first within equal keys): queries read it directly, so the
    # O(b log b) sort is paid once per stage/flush, not once per query.
    buf_sorted_kv: jax.Array         # int32[b]
    buf_sorted_val: jax.Array        # int32[b]
    # Compaction debt: per-level estimate of reclaimable (stale) residents,
    # measured on each run as a cascade step materializes it
    # (cascade.run_stale_count) and consumed by budgeted maintenance
    # (cleanup.lsm_maintain). A scheduling signal only — queries never read
    # it, and results are exact at any debt level (docs/DESIGN.md §11).
    lvl_debt: jax.Array              # int32[num_levels]


def level_view(cfg: LSMConfig, state: LSMState, i: int):
    """Level i as a (sorted, possibly all-placebo) run."""
    return state.key_vars[i], state.values[i]


def level_runs(cfg: LSMConfig, state: LSMState):
    """All levels as (key_vars, values) runs, newest (level 0) first."""
    return [level_view(cfg, state, i) for i in range(cfg.num_levels)]


def buffer_run(cfg: LSMConfig, state: LSMState):
    """The write buffer as a sorted run: ascending original key, newest
    (highest arrival seq) first within equal keys, placebos last. This is the
    run every query treats as the newest — buffer-resident tombstones hide
    older level elements before any flush. The sorted view is maintained by
    `lsm_stage`/`lsm_flush`, so reading it here costs nothing."""
    return state.buf_sorted_kv, state.buf_sorted_val


def all_runs(cfg: LSMConfig, state: LSMState):
    """Every queryable run, newest first: write buffer, then levels 0..L-1.

    The buffer run is included unconditionally (an empty buffer is all
    placebo, hence invisible) — no control flow depends on occupancy, same
    as the level arrays."""
    return [buffer_run(cfg, state)] + level_runs(cfg, state)


def arena_view(state: LSMState):
    """All levels concatenated (debug/test helper; excludes the buffer)."""
    return jnp.concatenate(state.key_vars), jnp.concatenate(state.values)


# Single definition lives in the cascade engine; re-exported here because
# cleanup/distributed/facade code historically imports it from this module.
_placebo = cascade._placebo


def _fresh_buffer(b: int) -> dict:
    """Field dict for an empty write buffer (for LSMState(...)/._replace)."""
    kv, val = _placebo(b)
    # The sorted view of an empty (all-placebo) buffer is itself all-placebo,
    # but it must be a DISTINCT buffer: aliasing buf_kv would make donation
    # see the same device buffer twice.
    sorted_kv, sorted_val = _placebo(b)
    return dict(
        buf_kv=kv,
        buf_val=val,
        buf_seq=jnp.full((b,), b, dtype=jnp.int32),
        buf_n=jnp.zeros((), dtype=jnp.int32),
        buf_sorted_kv=sorted_kv,
        buf_sorted_val=sorted_val,
    )


def compact_real(key_vars, values, mask):
    """Stable-partition the `mask` lanes to the front, arrival order
    preserved; remaining lanes become placebos. Returns (kv, val, count).

    Shared by the facade's `valid=` path and the sharded owner filter:
    masked-out lanes must never occupy write-buffer slots."""
    n = key_vars.shape[0]
    mask = jnp.asarray(mask, bool)
    count = jnp.sum(mask).astype(jnp.int32)
    pos = jnp.where(mask, jnp.cumsum(mask.astype(jnp.int32)) - 1, n)  # n -> dropped
    pk, pv = _placebo(n)
    out_kv = pk.at[pos].set(jnp.asarray(key_vars, jnp.int32), mode="drop")
    out_val = pv.at[pos].set(jnp.asarray(values, jnp.int32), mode="drop")
    return out_kv, out_val, count


def lsm_init(cfg: LSMConfig) -> LSMState:
    kvs, vals = zip(*(_placebo(cfg.level_size(i)) for i in range(cfg.num_levels)))
    return LSMState(
        key_vars=tuple(kvs),
        values=tuple(vals),
        r=jnp.zeros((), dtype=jnp.int32),
        overflowed=jnp.zeros((), dtype=bool),
        lvl_debt=jnp.zeros((cfg.num_levels,), dtype=jnp.int32),
        **_fresh_buffer(cfg.batch_size),
    )


# The binary-counter increment itself lives in the shared cascade engine
# (core/cascade.py): ONE lax.switch branch per placement level, each doing a
# single fused K-way merge of [carry, level 0..j-1] — the old pairwise
# cond-chain copied the carry past every level above the placement, making
# each update O(b * 2^L) regardless of where it landed.
_cascade = cascade.push_batch


def lsm_update(cfg: LSMConfig, state: LSMState, key_vars, values) -> LSMState:
    """Insert a mixed batch of b encoded updates (inserts and/or tombstones).

    Paper §3.2/§4.1: sort the batch by the full key variable, then cascade
    stable merges up the level hierarchy until an empty level receives the
    carry. Merges compare original keys only; newer runs win ties. Within the
    batch the full-key-variable sort makes a tombstone beat any same-batch
    insert of its key (paper invariant 2).

    This is the direct, paper-exact path: it bypasses the write buffer, so
    with a non-empty buffer the staged elements would (incorrectly) rank as
    newer than this batch — callers either keep the buffer empty (every
    direct-core user) or route through `lsm_stage` instead (the facade).
    """
    b = cfg.batch_size
    key_vars = jnp.asarray(key_vars, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    if key_vars.shape != (b,) or values.shape != (b,):
        raise ValueError(f"batch must have shape ({b},), got {key_vars.shape}/{values.shape}")
    carry_kv, carry_val = ops.sort_pairs(key_vars, values)
    return _cascade(cfg, state, carry_kv, carry_val)


def lsm_stage(cfg: LSMConfig, state: LSMState, key_vars, values, count) -> LSMState:
    """Stage one encoded sub-batch into the write buffer ("level −1").

    key_vars/values: int32[b] with the `count` real lanes compacted to the
    front *in arrival order* (use `compact_real` for masked inputs); the rest
    placebo. count: int32 scalar (traced OK), 0 <= count <= b.

    The sub-batch appends after the current buffer contents. If the combined
    occupancy stays <= b nothing else happens — no batch slot is consumed.
    Otherwise the *oldest* b pending elements flush through the cascade as
    one full batch (sorted newest-first within equal keys, so strict arrival
    order decides duplicates — docs/DESIGN.md §5) and the newest remainder
    stays in the buffer. At most one cascade per call: count <= b.
    """
    b = cfg.batch_size
    key_vars = jnp.asarray(key_vars, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    if key_vars.shape != (b,) or values.shape != (b,):
        raise ValueError(f"sub-batch must have shape ({b},), got {key_vars.shape}/{values.shape}")
    count = jnp.asarray(count, jnp.int32)
    lane = jnp.arange(b, dtype=jnp.int32)
    total = state.buf_n + count

    # Append into a 2b arena: [current buffer | placebo], incoming at buf_n+i.
    pk, pv = _placebo(b)
    pos = jnp.where(lane < count, state.buf_n + lane, 2 * b)  # 2b -> dropped
    arena_kv = jnp.concatenate([state.buf_kv, pk]).at[pos].set(key_vars, mode="drop")
    arena_val = jnp.concatenate([state.buf_val, pv]).at[pos].set(values, mode="drop")

    def no_flush(st):
        skv, sval = ops.sort_pairs_recency(arena_kv[:b], arena_val[:b])
        return st._replace(
            buf_kv=arena_kv[:b],
            buf_val=arena_val[:b],
            buf_seq=jnp.where(lane < total, lane, b),
            buf_n=total,
            buf_sorted_kv=skv,
            buf_sorted_val=sval,
        )

    def flush_oldest(st):
        # total > b => the first b arena lanes are all real, in arrival order.
        fk, fv = ops.sort_pairs_recency(arena_kv[:b], arena_val[:b])
        st = _cascade(cfg, st, fk, fv)
        rem = total - b
        skv, sval = ops.sort_pairs_recency(arena_kv[b:], arena_val[b:])
        return st._replace(
            buf_kv=arena_kv[b:],
            buf_val=arena_val[b:],
            buf_seq=jnp.where(lane < rem, lane, b),
            buf_n=rem,
            buf_sorted_kv=skv,
            buf_sorted_val=sval,
        )

    return jax.lax.cond(total > b, flush_oldest, no_flush, state)


def lsm_flush(cfg: LSMConfig, state: LSMState, min_pending: int = 1) -> LSMState:
    """Flush the write buffer through the cascade if it holds >= min_pending
    elements (no-op otherwise, and always a no-op when empty).

    A partial buffer is placebo-padded to a full batch — this consumes one
    batch slot for < b elements, exactly the facade's old pad-every-call
    cost, now paid only on explicit/threshold flushes."""
    def do(st):
        # The cached sorted view IS the cascade-ready batch.
        st = _cascade(cfg, st, st.buf_sorted_kv, st.buf_sorted_val)
        return st._replace(**_fresh_buffer(cfg.batch_size))

    pending = state.buf_n >= jnp.maximum(jnp.asarray(min_pending, jnp.int32), 1)
    return jax.lax.cond(pending, do, lambda st: st, state)


def lsm_insert(cfg: LSMConfig, state: LSMState, keys, values) -> LSMState:
    """Insert a batch of b (key, value) pairs (original keys, not encoded)."""
    return lsm_update(cfg, state, sem.encode_insert(keys), values)


def lsm_delete(cfg: LSMConfig, state: LSMState, keys) -> LSMState:
    """Delete a batch of b keys via tombstones (paper §3.3)."""
    kv = sem.encode_delete(keys)
    vals = jnp.full((cfg.batch_size,), sem.EMPTY_VALUE, dtype=jnp.int32)
    return lsm_update(cfg, state, kv, vals)


def lsm_update_mixed(cfg: LSMConfig, state: LSMState, keys, values, is_delete) -> LSMState:
    """Mixed batch: is_delete[i] selects tombstone vs regular insert."""
    kv = sem.encode(keys, is_delete)
    vals = jnp.where(jnp.asarray(is_delete), sem.EMPTY_VALUE, jnp.asarray(values, jnp.int32))
    return lsm_update(cfg, state, kv, vals)


def _redistribute(cfg: LSMConfig, compact_kv, compact_val, r_new):
    """Slice a globally sorted, deduplicated array into LSM levels.

    Level i (if bit i of r_new is set) receives the contiguous slice starting
    at b * (r_new & (2**i - 1)) — smallest keys land in the smallest levels
    (paper §4.5). Keys are unique after cleanup, so cross-level recency is
    irrelevant. (Thin alias of the engine's prefix-aware version.)
    """
    return cascade.redistribute(cfg, compact_kv, compact_val, r_new)


def lsm_bulk_build(cfg: LSMConfig, keys, values) -> LSMState:
    """Build from n unique keys: one sort + level segmentation (paper §5.2).

    n need not be a multiple of b: the tail of the last resident batch is
    placebo-padded, exactly the state CLEANUP produces for a non-multiple
    live count.
    """
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    k = -(-n // cfg.batch_size)  # ceil: last batch may be placebo-padded
    if k > cfg.max_batches:
        raise ValueError("bulk build exceeds configured capacity")
    kv, vals = ops.sort_pairs(sem.encode_insert(keys), values)
    pad = cfg.capacity - n
    kv = jnp.concatenate([kv, _placebo(pad)[0]])
    vals = jnp.concatenate([vals, _placebo(pad)[1]])
    kvs, vals = _redistribute(cfg, kv, vals, jnp.asarray(k, jnp.int32))
    return LSMState(
        key_vars=kvs,
        values=vals,
        r=jnp.asarray(k, jnp.int32),
        overflowed=jnp.zeros((), dtype=bool),
        lvl_debt=jnp.zeros((cfg.num_levels,), dtype=jnp.int32),
        **_fresh_buffer(cfg.batch_size),
    )


def lsm_num_elements(cfg: LSMConfig, state: LSMState):
    """Resident element count (including stale elements): r * b + staged."""
    return state.r * cfg.batch_size + state.buf_n


def lsm_debt(cfg: LSMConfig, state: LSMState):
    """Total compaction debt (int32 scalar): the per-level stale-resident
    estimate summed over levels. What `lsm_maintain` budgets against."""
    return jnp.sum(state.lvl_debt).astype(jnp.int32)


def lsm_flush_cost(cfg: LSMConfig, state: LSMState):
    """Elements the cascade would touch if the buffer flushed *now* (int32
    scalar; 0 when the buffer is empty).

    Pushing one batch into the binary counter merges through the trailing-one
    levels of r (each full level is carried), so the merge reads and rewrites
    b * (trailing_ones(r) + 1) arena elements. This is the cost the serving
    scheduler weighs against buffer occupancy when deciding whether to flush
    early or keep absorbing trickles (repro.serve.server admission policy).
    """
    trailing = jnp.zeros((), jnp.int32)
    run = jnp.ones((), bool)
    for lvl in range(cfg.num_levels):
        run = run & (((state.r >> lvl) & 1) == 1)
        trailing = trailing + run.astype(jnp.int32)
    cost = cfg.batch_size * (trailing + 1)
    return jnp.where(state.buf_n > 0, cost, 0).astype(jnp.int32)
