"""TPU LSM: the paper's data structure as a fixed-shape, jit-native JAX module.

Layout (per-level arrays)
-------------------------
A GPU allocates levels lazily; a jit/pjit program needs static shapes. We
preallocate `num_levels` exponentially sized levels as separate arrays —
level i holds exactly b * 2**i slots. Keeping levels as distinct buffers (not
one flat arena) matters for the complexity story: a batch update rewrites
ONLY the levels the binary-counter carry touches (lax.switch pass-through +
buffer donation forwards untouched levels), preserving the paper's
O(b log r) amortized insertion cost. A flat arena would force an O(capacity)
rewrite per batch.

Empty levels (and the tails of cleaned-up levels) hold *placebo* elements —
maximum original key + tombstone status (paper §4.5 fn. 6) — which sort last
and are invisible to every query. "Empty" and "full" levels are therefore
indistinguishable to query code: no control flow depends on occupancy.

The resident-batch counter `r` mirrors the paper exactly: level i is full iff
bit i of r is set, and a batch update is a binary-counter increment whose
carries are stable merges.

Everything here is traceable: `LSMConfig` is static (hashable) and `LSMState`
is a pytree, so `jax.jit(lsm_update, static_argnums=0, donate_argnums=1)`
works, as does sharding each level with pjit/shard_map (core/distributed.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LSMConfig:
    """Static configuration: batch size b and level count L (capacity b*(2^L-1))."""

    batch_size: int
    num_levels: int

    def __post_init__(self):
        if self.batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if self.num_levels < 1:
            raise ValueError("num_levels must be >= 1")

    @property
    def capacity(self) -> int:
        return self.batch_size * ((1 << self.num_levels) - 1)

    @property
    def max_batches(self) -> int:
        return (1 << self.num_levels) - 1

    def level_size(self, i: int) -> int:
        return self.batch_size * (1 << i)


class LSMState(NamedTuple):
    """Pytree state: per-level (key_var, value) arrays + counter + overflow latch."""

    key_vars: Tuple[jax.Array, ...]  # level i: int32[b * 2**i]
    values: Tuple[jax.Array, ...]
    r: jax.Array                     # int32[] — number of resident batches
    overflowed: jax.Array            # bool[] — latches if an update overflowed


def level_view(cfg: LSMConfig, state: LSMState, i: int):
    """Level i as a (sorted, possibly all-placebo) run."""
    return state.key_vars[i], state.values[i]


def level_runs(cfg: LSMConfig, state: LSMState):
    """All levels as (key_vars, values) runs, newest (level 0) first."""
    return [level_view(cfg, state, i) for i in range(cfg.num_levels)]


def arena_view(state: LSMState):
    """All levels concatenated (debug/test helper)."""
    return jnp.concatenate(state.key_vars), jnp.concatenate(state.values)


def _placebo(n):
    return (
        jnp.full((n,), sem.PLACEBO_KV, dtype=jnp.int32),
        jnp.full((n,), sem.EMPTY_VALUE, dtype=jnp.int32),
    )


def lsm_init(cfg: LSMConfig) -> LSMState:
    kvs, vals = zip(*(_placebo(cfg.level_size(i)) for i in range(cfg.num_levels)))
    return LSMState(
        key_vars=tuple(kvs),
        values=tuple(vals),
        r=jnp.zeros((), dtype=jnp.int32),
        overflowed=jnp.zeros((), dtype=bool),
    )


def lsm_update(cfg: LSMConfig, state: LSMState, key_vars, values) -> LSMState:
    """Insert a mixed batch of b encoded updates (inserts and/or tombstones).

    Paper §3.2/§4.1: sort the batch by the full key variable, then cascade
    stable merges up the level hierarchy until an empty level receives the
    carry. Merges compare original keys only; newer runs win ties.

    Per level, one of three things happens (lax.switch):
      0 keep  — level above the carry path: buffer passes through untouched;
      1 place — first empty level: receives the carry;
      2 clear — full level consumed by the carry merge: reset to placebos.
    """
    b = cfg.batch_size
    key_vars = jnp.asarray(key_vars, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    if key_vars.shape != (b,) or values.shape != (b,):
        raise ValueError(f"batch must have shape ({b},), got {key_vars.shape}/{values.shape}")

    would_overflow = state.r >= cfg.max_batches

    carry_kv, carry_val = ops.sort_pairs(key_vars, values)
    placed = jnp.asarray(False)
    new_kvs = list(state.key_vars)
    new_vals = list(state.values)

    for i in range(cfg.num_levels):
        lvl_kv, lvl_val = new_kvs[i], new_vals[i]
        n = cfg.level_size(i)
        full = ((state.r >> i) & 1) == 1
        do_merge = full & ~placed & ~would_overflow
        do_place = (~full) & (~placed) & ~would_overflow

        case = do_merge.astype(jnp.int32) * 2 + do_place.astype(jnp.int32)
        new_kvs[i], new_vals[i] = jax.lax.switch(
            case,
            [
                lambda lk, lv, ck, cv: (lk, lv),            # keep
                lambda lk, lv, ck, cv: (ck, cv),            # place carry
                lambda lk, lv, ck, cv, n=n: _placebo(n),    # cleared by merge
            ],
            lvl_kv, lvl_val, carry_kv, carry_val,
        )

        if i + 1 < cfg.num_levels:
            def _merge(ck, cv, lk, lv):
                return ops.merge_sorted(ck, cv, lk, lv)

            def _skip(ck, cv, lk, lv, n=n):
                pk, pv = _placebo(n)
                return jnp.concatenate([ck, pk]), jnp.concatenate([cv, pv])

            carry_kv, carry_val = jax.lax.cond(
                do_merge, _merge, _skip, carry_kv, carry_val, lvl_kv, lvl_val
            )
        placed = placed | do_place

    return LSMState(
        key_vars=tuple(new_kvs),
        values=tuple(new_vals),
        r=jnp.where(would_overflow, state.r, state.r + 1),
        overflowed=state.overflowed | would_overflow,
    )


def lsm_insert(cfg: LSMConfig, state: LSMState, keys, values) -> LSMState:
    """Insert a batch of b (key, value) pairs (original keys, not encoded)."""
    return lsm_update(cfg, state, sem.encode_insert(keys), values)


def lsm_delete(cfg: LSMConfig, state: LSMState, keys) -> LSMState:
    """Delete a batch of b keys via tombstones (paper §3.3)."""
    kv = sem.encode_delete(keys)
    vals = jnp.full((cfg.batch_size,), sem.EMPTY_VALUE, dtype=jnp.int32)
    return lsm_update(cfg, state, kv, vals)


def lsm_update_mixed(cfg: LSMConfig, state: LSMState, keys, values, is_delete) -> LSMState:
    """Mixed batch: is_delete[i] selects tombstone vs regular insert."""
    kv = sem.encode(keys, is_delete)
    vals = jnp.where(jnp.asarray(is_delete), sem.EMPTY_VALUE, jnp.asarray(values, jnp.int32))
    return lsm_update(cfg, state, kv, vals)


def _redistribute(cfg: LSMConfig, compact_kv, compact_val, r_new):
    """Slice a globally sorted, deduplicated array into LSM levels.

    Level i (if bit i of r_new is set) receives the contiguous slice starting
    at b * (r_new & (2**i - 1)) — smallest keys land in the smallest levels
    (paper §4.5). Keys are unique after cleanup, so cross-level recency is
    irrelevant.
    """
    b = cfg.batch_size
    kvs, vals = [], []
    for i in range(cfg.num_levels):
        n = cfg.level_size(i)
        bit = ((r_new >> i) & 1) == 1
        src_start = b * (r_new & ((1 << i) - 1))
        sl_kv = jax.lax.dynamic_slice(compact_kv, (src_start,), (n,))
        sl_val = jax.lax.dynamic_slice(compact_val, (src_start,), (n,))
        pk, pv = _placebo(n)
        kvs.append(jnp.where(bit, sl_kv, pk))
        vals.append(jnp.where(bit, sl_val, pv))
    return tuple(kvs), tuple(vals)


def lsm_bulk_build(cfg: LSMConfig, keys, values) -> LSMState:
    """Build from n unique keys: one sort + level segmentation (paper §5.2).

    n need not be a multiple of b: the tail of the last resident batch is
    placebo-padded, exactly the state CLEANUP produces for a non-multiple
    live count.
    """
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    k = -(-n // cfg.batch_size)  # ceil: last batch may be placebo-padded
    if k > cfg.max_batches:
        raise ValueError("bulk build exceeds configured capacity")
    kv, vals = ops.sort_pairs(sem.encode_insert(keys), values)
    pad = cfg.capacity - n
    kv = jnp.concatenate([kv, _placebo(pad)[0]])
    vals = jnp.concatenate([vals, _placebo(pad)[1]])
    kvs, vals = _redistribute(cfg, kv, vals, jnp.asarray(k, jnp.int32))
    return LSMState(
        key_vars=kvs,
        values=vals,
        r=jnp.asarray(k, jnp.int32),
        overflowed=jnp.zeros((), dtype=bool),
    )


def lsm_num_elements(cfg: LSMConfig, state: LSMState):
    """Resident element count (including stale elements), r * b."""
    return state.r * cfg.batch_size
