"""Bulk queries over the LSM: lookup, count, range (paper §3.4–3.5, §4.2–4.4).

All three queries are expressed over *runs*: a list of sorted (key_var, value)
arrays ordered newest-first. The LSM passes its levels (level 0 first); the
sorted-array baseline passes its single run — the validation logic is shared.

The count/range pipeline is the paper's five-stage bulk algorithm, adapted to
fixed shapes (TPU-native: no dynamic allocation):
  1. per-run lower/upper bound binary searches            (paper stage 1)
  2. per-query candidate offsets via prefix sums          (paper stage 2)
  3. gather candidates into a [num_queries, max_candidates]
     padded tile, placebo-filled                          (paper stage 3)
  4. row-wise stable sort by original key — the segmented
     sort; recency order is preserved by stability        (paper stage 4)
  5. mask arithmetic validation: count/emit the first
     element of each equal-key segment iff it is regular  (paper stage 5)

The paper's warp-ballot counting in stage 5 has no TPU analogue; dense mask
arithmetic over the padded tile is the VPU-idiomatic equivalent
(docs/DESIGN.md §8).

The LSM entry points query `all_runs`: the write buffer (sorted on demand,
newest-first within equal keys — docs/DESIGN.md §5) is the newest run, so
staged sub-batch updates — including buffer-resident tombstones — are visible
to lookup/count/range/size before any flush.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.core.lsm import LSMConfig, LSMState, all_runs
from repro.kernels import ops

INT32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# LOOKUP
# ---------------------------------------------------------------------------


def lookup_runs(runs, query_keys):
    """LOOKUP(k) over newest-first runs: first matching run wins; tombstone → ⊥.

    On the Pallas backend the whole resolution collapses into one fused
    streaming kernel over the concatenated runs (`ops.lookup_runs_fused`);
    the per-run loop below is the XLA path and the semantic reference the
    fused kernel is tested against (tests/test_fused_kernels.py).
    """
    query_keys = jnp.asarray(query_keys, jnp.int32)
    fused = ops.lookup_runs_fused(runs, query_keys)
    if fused is not None:
        return fused
    nq = query_keys.shape[0]
    resolved = jnp.zeros((nq,), dtype=bool)
    found = jnp.zeros((nq,), dtype=bool)
    result = jnp.full((nq,), sem.EMPTY_VALUE, dtype=jnp.int32)
    for kv, val in runs:
        hit, tomb, v = ops.lookup_level(kv, val, query_keys)
        newly = hit & ~resolved
        found = found | (newly & ~tomb)
        result = jnp.where(newly & ~tomb, v, result)
        resolved = resolved | newly
    return found, result


def lsm_lookup(cfg: LSMConfig, state: LSMState, query_keys):
    """Batched LOOKUP: returns (found: bool[nq], values: int32[nq])."""
    return lookup_runs(all_runs(cfg, state), query_keys)


# ---------------------------------------------------------------------------
# COUNT / RANGE candidate pipeline
# ---------------------------------------------------------------------------


def _gather_candidates(runs, k1, k2, max_candidates):
    """Stages 1–4: gather + segment-sort candidates for [k1, k2] queries.

    Returns (orig, kv, val, total, ok):
      orig/kv/val: [nq, max_candidates] row-sorted by original key, stable in
        recency (newest first within equal keys); placebo padding sorts last.
      total: exact number of candidates per query (before truncation).
      ok: total <= max_candidates (results are exact iff ok).
    """
    k1 = jnp.asarray(k1, jnp.int32)
    k2 = jnp.asarray(k2, jnp.int32)
    nq = k1.shape[0]
    n_runs = len(runs)

    lows, counts = [], []
    for kv, _ in runs:
        orig = sem.original_key(kv)
        lo = ops.lower_bound(orig, k1)
        hi = ops.upper_bound(orig, k2)
        lows.append(lo)
        counts.append(jnp.maximum(hi - lo, 0))
    counts_m = jnp.stack(counts, axis=0)          # [n_runs, nq]
    offsets = jnp.cumsum(counts_m, axis=0) - counts_m  # exclusive scan over runs
    total = jnp.sum(counts_m, axis=0)             # [nq]
    ok = total <= max_candidates

    # Stage 3: slot j of a query row maps to (run, within-run index).
    slots = jnp.arange(max_candidates, dtype=jnp.int32)[None, :]  # [1, M]
    gather_idx = jnp.zeros((nq, max_candidates), dtype=jnp.int32)
    valid_slot = jnp.zeros((nq, max_candidates), dtype=bool)
    flat_starts = []
    start = 0
    for kv, _ in runs:
        flat_starts.append(start)
        start += kv.shape[0]
    for r in range(n_runs):
        off = offsets[r][:, None]                 # [nq, 1]
        cnt = counts_m[r][:, None]
        sel = (slots >= off) & (slots < off + cnt)
        idx = flat_starts[r] + lows[r][:, None] + (slots - off)
        gather_idx = jnp.where(sel, idx, gather_idx)
        valid_slot = valid_slot | sel

    all_kv = jnp.concatenate([kv for kv, _ in runs])
    all_val = jnp.concatenate([val for _, val in runs])
    cand_kv = jnp.where(valid_slot, all_kv[gather_idx], sem.PLACEBO_KV)
    cand_val = jnp.where(valid_slot, all_val[gather_idx], sem.EMPTY_VALUE)

    # Stage 4: segmented (row-wise) stable sort by ORIGINAL key. Rows were
    # built newest-run-first, so stability preserves recency within segments.
    cand_orig = sem.original_key(cand_kv)
    sort_row = lambda o, kv, v: jax.lax.sort((o, kv, v), dimension=0, is_stable=True, num_keys=1)
    orig_s, kv_s, val_s = jax.vmap(sort_row)(cand_orig, cand_kv, cand_val)
    return orig_s, kv_s, val_s, total, ok


def _validate(orig_s, kv_s):
    """Stage 5: first element of each equal-key segment, iff regular."""
    nq, m = orig_s.shape
    prev = jnp.concatenate([jnp.full((nq, 1), -1, jnp.int32), orig_s[:, :-1]], axis=1)
    first_of_segment = orig_s != prev
    regular = ~sem.is_tombstone(kv_s)
    not_placebo = orig_s != sem.PLACEBO_KEY
    return first_of_segment & regular & not_placebo


def count_runs(runs, k1, k2, max_candidates):
    """COUNT(k1, k2) over runs. Returns (counts: int32[nq], ok: bool[nq])."""
    orig_s, kv_s, _, _, ok = _gather_candidates(runs, k1, k2, max_candidates)
    valid = _validate(orig_s, kv_s)
    return jnp.sum(valid, axis=1).astype(jnp.int32), ok


def range_runs(runs, k1, k2, max_candidates, max_results):
    """RANGE(k1, k2): compacted per-query results.

    Returns (keys [nq, max_results], values [nq, max_results], counts, ok).
    Rows are padded with PLACEBO_KEY / EMPTY_VALUE beyond `counts`.
    """
    orig_s, kv_s, val_s, _, ok = _gather_candidates(runs, k1, k2, max_candidates)
    valid = _validate(orig_s, kv_s)
    counts = jnp.sum(valid, axis=1).astype(jnp.int32)
    ok = ok & (counts <= max_results)

    nq, m = orig_s.shape
    tgt = jnp.cumsum(valid, axis=1) - 1
    tgt = jnp.where(valid & (tgt < max_results), tgt, max_results)  # drop slot
    rows = jnp.broadcast_to(jnp.arange(nq)[:, None], (nq, m))
    out_keys = jnp.full((nq, max_results), sem.PLACEBO_KEY, dtype=jnp.int32)
    out_vals = jnp.full((nq, max_results), sem.EMPTY_VALUE, dtype=jnp.int32)
    out_keys = out_keys.at[rows, tgt].set(orig_s, mode="drop")
    out_vals = out_vals.at[rows, tgt].set(val_s, mode="drop")
    return out_keys, out_vals, counts, ok


def survivor_mask(key_vars):
    """The CLEANUP survivor rule over one sorted run: an element is visible
    iff it is the first (most recent) element of its equal-key segment, is
    regular (not a tombstone), and is not a placebo. Single source of truth
    for cleanup (LSM and SA) and live-size accounting."""
    orig = sem.original_key(key_vars)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), orig[:-1]])
    return (orig != prev) & (~sem.is_tombstone(key_vars)) & (orig != sem.PLACEBO_KEY)


def valid_count_runs(runs):
    """Number of live (visible) elements across newest-first runs.

    Shared by every run-based backend (`Dictionary.size`): one K-way stable
    newest-first merge of the runs, then count the survivors.
    """
    merged_kv, _ = ops.merge_cascade(runs)
    return jnp.sum(survivor_mask(merged_kv)).astype(jnp.int32)


def lsm_count(cfg: LSMConfig, state: LSMState, k1, k2, max_candidates: int):
    return count_runs(all_runs(cfg, state), k1, k2, max_candidates)


def lsm_range(cfg: LSMConfig, state: LSMState, k1, k2, max_candidates: int, max_results: int):
    return range_runs(all_runs(cfg, state), k1, k2, max_candidates, max_results)
