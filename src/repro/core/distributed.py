"""Range-partitioned distributed LSM over a device mesh (shard_map).

Each device owns a contiguous key range (region-server model, as in
BigTable/HBase — chosen over hash partitioning because RANGE/COUNT queries
then touch only the owning shards). Every device runs a full local LSM over
its range:

  * UPDATE: the global batch is all-gathered; each shard filters the keys it
    owns and turns the rest into placebo padding — the batch-of-b invariant
    holds per shard, so the local binary-counter cascade is unchanged. (The
    all-gather is the TPU-native stand-in for a ragged all-to-all; bytes moved are
    identical up to the skew factor and the shapes stay static.)
  * LOOKUP: queries are broadcast; the owner answers; results combine with
    a max-reduction using ⊥-identities (non-owners contribute 0/false).
  * COUNT: local counts + psum.
  * RANGE: local compacted results + per-shard counts; the caller assembles
    (offsets are an exclusive psum over shard counts).
  * CLEANUP: purely shard-local (no communication at all) — a nice property
    of range partitioning the paper's structure inherits for free.

The key space [0, MAX_USER_KEY] is split evenly; shard s owns
[s * range_size, (s+1) * range_size).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import semantics as sem
from repro.core.cleanup import lsm_cleanup
from repro.core.lsm import LSMConfig, LSMState, lsm_init, lsm_update
from repro.core.queries import count_runs, lookup_runs, range_runs
from repro.core.lsm import level_runs


@dataclasses.dataclass(frozen=True)
class DistLSMConfig:
    local: LSMConfig          # per-shard LSM config (batch_size = global batch!)
    num_shards: int
    axis: str = "shard"

    @property
    def range_size(self) -> int:
        return (sem.PLACEBO_KEY + self.num_shards - 1) // self.num_shards


def owner_of(cfg: DistLSMConfig, keys):
    return jnp.clip(jnp.asarray(keys, jnp.int32) // cfg.range_size, 0, cfg.num_shards - 1)


def dist_lsm_init(cfg: DistLSMConfig, mesh) -> LSMState:
    """Per-shard LSM states, stacked on a leading sharded axis."""
    def init_one(_):
        return lsm_init(cfg.local)

    states = jax.vmap(init_one)(jnp.arange(cfg.num_shards))
    specs = jax.tree_util.tree_map(lambda l: P(cfg.axis, *([None] * (l.ndim - 1))), states)
    return jax.device_put(states, jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), specs))


def _local_state(stacked: LSMState) -> LSMState:
    """Strip the leading (size-1 per shard) stacking axis inside shard_map."""
    return jax.tree_util.tree_map(lambda x: x[0], stacked)


def _restack(state: LSMState) -> LSMState:
    return jax.tree_util.tree_map(lambda x: x[None], state)


def make_dist_update(cfg: DistLSMConfig, mesh):
    """Returns jitted update(states, key_vars[b], values[b]) -> states."""
    state_spec = P(cfg.axis)

    def body(states, key_vars, values):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        owner = owner_of(cfg, sem.original_key(key_vars))
        mine = owner == shard
        kv = jnp.where(mine, key_vars, sem.PLACEBO_KV)
        val = jnp.where(mine, values, sem.EMPTY_VALUE)
        st = lsm_update(cfg.local, st, kv, val)
        return _restack(st)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=state_spec,
        check_vma=False,
    )
    return jax.jit(f, donate_argnums=0)


def make_dist_lookup(cfg: DistLSMConfig, mesh):
    """Returns jitted lookup(states, keys[q]) -> (found[q], values[q])."""
    state_spec = P(cfg.axis)

    def body(states, keys):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        mine = owner_of(cfg, keys) == shard
        found, vals = lookup_runs(level_runs(cfg.local, st), keys)
        found = found & mine
        vals = jnp.where(found, vals, 0)
        # ⊥-identity combine: exactly one shard can report found.
        found = jax.lax.pmax(found.astype(jnp.int32), cfg.axis) > 0
        vals = jax.lax.pmax(vals, cfg.axis)
        return found[None], vals[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(states, keys):
        found, vals = f(states, keys)
        return found[0], vals[0]

    return jax.jit(run)


def make_dist_count(cfg: DistLSMConfig, mesh, max_candidates: int):
    """Returns jitted count(states, k1[q], k2[q]) -> (counts[q], ok[q]).

    Each shard counts the intersection of [k1, k2] with its own range;
    global count = psum. Clipping to the shard range keeps per-shard
    candidate buffers small (max_candidates is per shard).
    """
    state_spec = P(cfg.axis)

    def body(states, k1, k2):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        lo = shard * cfg.range_size
        hi = lo + cfg.range_size - 1
        k1c = jnp.clip(k1, lo, hi + 1)
        k2c = jnp.clip(k2, lo - 1, hi)
        nonempty = k1c <= k2c
        counts, ok = count_runs(level_runs(cfg.local, st), k1c, k2c, max_candidates)
        counts = jnp.where(nonempty, counts, 0)
        ok = ok | ~nonempty
        counts = jax.lax.psum(counts, cfg.axis)
        ok = jax.lax.pmin(ok.astype(jnp.int32), cfg.axis) > 0
        return counts[None], ok[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def run(states, k1, k2):
        c, ok = f(states, k1, k2)
        return c[0], ok[0]

    return jax.jit(run)


def make_dist_range(cfg: DistLSMConfig, mesh, max_candidates: int, max_results: int):
    """Returns jitted range(states, k1[q], k2[q]) ->
    (keys [shards, q, max_results], vals, counts [shards, q], ok[q]).

    Results stay shard-major (keys within a shard ascending; shards ascending
    = globally ascending since partitioning is by range). The caller can
    compact with the per-shard counts.
    """
    state_spec = P(cfg.axis)

    def body(states, k1, k2):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        lo = shard * cfg.range_size
        hi = lo + cfg.range_size - 1
        k1c = jnp.clip(k1, lo, hi + 1)
        k2c = jnp.clip(k2, lo - 1, hi)
        nonempty = (k1c <= k2c)
        keys, vals, counts, ok = range_runs(
            level_runs(cfg.local, st), k1c, k2c, max_candidates, max_results
        )
        counts = jnp.where(nonempty, counts, 0)
        ok = ok | ~nonempty
        ok = jax.lax.pmin(ok.astype(jnp.int32), cfg.axis) > 0
        return keys[None], vals[None], counts[None], ok[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=(state_spec, state_spec, state_spec, P()),
        check_vma=False,
    )

    def run(states, k1, k2):
        keys, vals, counts, ok = f(states, k1, k2)
        return keys, vals, counts, ok[0]

    return jax.jit(run)


def make_dist_cleanup(cfg: DistLSMConfig, mesh):
    """Shard-local cleanup — zero communication."""
    state_spec = P(cfg.axis)

    def body(states):
        return _restack(lsm_cleanup(cfg.local, _local_state(states)))

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=state_spec,
                  check_vma=False)
    return jax.jit(f, donate_argnums=0)
