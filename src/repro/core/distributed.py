"""Range-partitioned distributed LSM over a device mesh (shard_map).

Each device owns a contiguous key range (region-server model, as in
BigTable/HBase — chosen over hash partitioning because RANGE/COUNT queries
then touch only the owning shards). Every device runs a full local LSM over
its range:

  * UPDATE: the global batch is all-gathered; each shard filters the keys it
    owns and turns the rest into placebo padding — the batch-of-b invariant
    holds per shard, so the local binary-counter cascade is unchanged. (The
    all-gather is the TPU-native stand-in for a ragged all-to-all; bytes moved are
    identical up to the skew factor and the shapes stay static.)
  * STAGE (write buffer): same ownership filter, then owned lanes compact to
    the front (arrival order preserved) and append into the shard-LOCAL write
    buffer (`lsm_stage`) — zero communication beyond the already-replicated
    batch, and no batch slot consumed until a shard's own buffer overflows.
    Buffers fill at ownership-skew-dependent rates, so shards flush at
    different times; FLUSH is likewise purely shard-local.
  * LOOKUP: queries are broadcast; the owner answers; results combine with
    a psum using ⊥-identities (non-owners contribute 0/false, exactly one
    owner can report found, so the sum IS the owner's answer — unlike a max
    combine this stays correct for negative payload values).
  * COUNT: local counts + psum.
  * RANGE: local compacted results + per-shard counts; `assemble_range`
    turns the shard-major stack into globally compacted rows (offsets are
    an exclusive cumsum over shard counts).
  * CLEANUP: purely shard-local (no communication at all) — a nice property
    of range partitioning the paper's structure inherits for free.
  * SIZE / BULK_BUILD: local survivor count + psum; local build over the
    owned subset of a replicated key set.

The key space [0, MAX_USER_KEY] is split evenly; shard s owns
[s * range_size, (s+1) * range_size).

Two API layers:

  * `dist_update` / `dist_lookup` / ... are *traceable*: plain functions of
    (cfg, mesh, state, ...) that build their shard_map at trace time, so the
    `Dictionary` facade can call them inside its own jitted executables
    (backend "lsm_sharded" in repro.api.backends).
  * `make_dist_*` wrap them in standalone jitted callables with donation —
    the original surface, kept for direct core users and the distributed
    tests.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import semantics as sem
from repro.core.cleanup import lsm_cleanup, lsm_maintain
from repro.core.lsm import (
    LSMConfig,
    LSMState,
    _fresh_buffer,
    _placebo,
    _redistribute,
    compact_real,
    lsm_debt,
    lsm_flush,
    lsm_flush_cost,
    lsm_init,
    lsm_stage,
    lsm_update,
)
from repro.core.queries import count_runs, lookup_runs, range_runs, valid_count_runs
from repro.core.lsm import all_runs
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class DistLSMConfig:
    local: LSMConfig          # per-shard LSM config (batch_size = global batch!)
    num_shards: int
    axis: str = "shard"

    @property
    def range_size(self) -> int:
        return (sem.PLACEBO_KEY + self.num_shards - 1) // self.num_shards


def owner_of(cfg: DistLSMConfig, keys):
    return jnp.clip(jnp.asarray(keys, jnp.int32) // cfg.range_size, 0, cfg.num_shards - 1)


def shard_bounds(cfg: DistLSMConfig, shard):
    """Inclusive [lo, hi] key range owned by `shard` (traced or static)."""
    lo = shard * cfg.range_size
    hi = lo + cfg.range_size - 1
    return lo, hi


def dist_lsm_init(cfg: DistLSMConfig, mesh) -> LSMState:
    """Per-shard LSM states, stacked on a leading sharded axis."""
    from repro.dist.sharding import stacked_shardings

    def init_one(_):
        return lsm_init(cfg.local)

    states = jax.vmap(init_one)(jnp.arange(cfg.num_shards))
    return jax.device_put(states, stacked_shardings(states, mesh, cfg.axis))


def _local_state(stacked: LSMState) -> LSMState:
    """Strip the leading (size-1 per shard) stacking axis inside shard_map."""
    return jax.tree_util.tree_map(lambda x: x[0], stacked)


def _restack(state: LSMState) -> LSMState:
    return jax.tree_util.tree_map(lambda x: x[None], state)


# ---------------------------------------------------------------------------
# Traceable ops (safe to call inside an enclosing jit — the facade does)
# ---------------------------------------------------------------------------


def dist_update(cfg: DistLSMConfig, mesh, states, key_vars, values) -> LSMState:
    """Apply one b-wide encoded batch: each shard keeps its keys, placebos the
    rest, and runs the unchanged local binary-counter cascade."""
    state_spec = P(cfg.axis)

    def body(states, key_vars, values):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        owner = owner_of(cfg, sem.original_key(key_vars))
        mine = owner == shard
        kv = jnp.where(mine, key_vars, sem.PLACEBO_KV)
        val = jnp.where(mine, values, sem.EMPTY_VALUE)
        st = lsm_update(cfg.local, st, kv, val)
        return _restack(st)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=state_spec,
        check_vma=False,
    )
    return f(states, key_vars, values)


def dist_stage(cfg: DistLSMConfig, mesh, states, key_vars, values, count) -> LSMState:
    """Stage one encoded sub-batch into the shard-local write buffers.

    key_vars/values: int32[b] with the `count` real lanes front-compacted in
    arrival order (the facade's contract for `stage_encoded`). Each shard
    keeps its owned lanes, re-compacts them to the front (order preserved),
    and appends to its LOCAL buffer — no communication beyond the replicated
    input, and no batch slot consumed until that shard's buffer overflows.
    """
    state_spec = P(cfg.axis)

    def body(states, key_vars, values, count):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        lane = jnp.arange(cfg.local.batch_size, dtype=jnp.int32)
        owner = owner_of(cfg, sem.original_key(key_vars))
        mine = (lane < count) & (owner == shard)
        kv, val, cnt = compact_real(key_vars, values, mine)
        st = lsm_stage(cfg.local, st, kv, val, cnt)
        return _restack(st)

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P(), P()),
        out_specs=state_spec,
        check_vma=False,
    )
    return f(states, key_vars, values, count)


def dist_flush(cfg: DistLSMConfig, mesh, states, min_pending: int = 1) -> LSMState:
    """Flush shard-local write buffers holding >= min_pending elements.

    Purely shard-local (zero communication) — shards flush independently, so
    ownership skew never forces an empty shard to burn a batch slot."""
    state_spec = P(cfg.axis)

    def body(states):
        return _restack(lsm_flush(cfg.local, _local_state(states), min_pending))

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=state_spec,
                  check_vma=False)
    return f(states)


def dist_pending(cfg: DistLSMConfig, mesh, states):
    """Total write-buffer residents across shards (int32 scalar, psum)."""
    state_spec = P(cfg.axis)

    def body(states):
        return jax.lax.psum(_local_state(states).buf_n, cfg.axis)

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=P(),
                  check_vma=False)
    return f(states)


def dist_occupancy(cfg: DistLSMConfig, mesh, states):
    """(pending, resident, debt) int32 scalars summed across shards.

    Shard-local reads + three psums — cheap enough for a serving scheduler to
    poll between coalesced steps (no query machinery runs)."""
    state_spec = P(cfg.axis)

    def body(states):
        local = _local_state(states)
        pending = jax.lax.psum(local.buf_n, cfg.axis)
        resident = jax.lax.psum(local.r * cfg.local.batch_size, cfg.axis)
        debt = jax.lax.psum(lsm_debt(cfg.local, local), cfg.axis)
        return pending, resident, debt

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,),
                  out_specs=(P(), P(), P()), check_vma=False)
    return f(states)


def dist_flush_cost(cfg: DistLSMConfig, mesh, states):
    """Total elements every shard's cascade would touch on a flush now (psum
    of the shard-local `lsm_flush_cost`; shards flush independently, so the
    sum is the whole-device-step work estimate)."""
    state_spec = P(cfg.axis)

    def body(states):
        return jax.lax.psum(
            lsm_flush_cost(cfg.local, _local_state(states)), cfg.axis
        )

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=P(),
                  check_vma=False)
    return f(states)


def dist_lookup(cfg: DistLSMConfig, mesh, states, keys):
    """lookup(states, keys[q]) -> (found[q], values[q])."""
    state_spec = P(cfg.axis)

    def body(states, keys):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        mine = owner_of(cfg, keys) == shard
        found, vals = lookup_runs(all_runs(cfg.local, st), keys)
        found = found & mine
        vals = jnp.where(found, vals, 0)
        # ⊥-identity combine: exactly one shard can report found, everyone
        # else contributes 0, so psum reconstructs the owner's value exactly
        # (correct even for negative payloads, unlike a max combine).
        found = jax.lax.psum(found.astype(jnp.int32), cfg.axis) > 0
        vals = jax.lax.psum(vals, cfg.axis)
        return found[None], vals[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    found, vals = f(states, keys)
    return found[0], vals[0]


def dist_count(cfg: DistLSMConfig, mesh, states, k1, k2, max_candidates: int):
    """count(states, k1[q], k2[q]) -> (counts[q], ok[q]).

    Each shard counts the intersection of [k1, k2] with its own range;
    global count = psum. Clipping to the shard range keeps per-shard
    candidate buffers small (max_candidates is per shard).
    """
    state_spec = P(cfg.axis)

    def body(states, k1, k2):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        lo, hi = shard_bounds(cfg, shard)
        k1c = jnp.clip(k1, lo, hi + 1)
        k2c = jnp.clip(k2, lo - 1, hi)
        nonempty = k1c <= k2c
        counts, ok = count_runs(all_runs(cfg.local, st), k1c, k2c, max_candidates)
        counts = jnp.where(nonempty, counts, 0)
        ok = ok | ~nonempty
        counts = jax.lax.psum(counts, cfg.axis)
        ok = jax.lax.pmin(ok.astype(jnp.int32), cfg.axis) > 0
        return counts[None], ok[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    c, ok = f(states, k1, k2)
    return c[0], ok[0]


def dist_range(cfg: DistLSMConfig, mesh, states, k1, k2,
               max_candidates: int, max_results: int):
    """range(states, k1[q], k2[q]) ->
    (keys [shards, q, max_results], vals, counts [shards, q], ok[q]).

    Results stay shard-major (keys within a shard ascending; shards ascending
    = globally ascending since partitioning is by range). Use
    `assemble_range` for globally compacted per-query rows.
    """
    state_spec = P(cfg.axis)

    def body(states, k1, k2):
        st = _local_state(states)
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        lo, hi = shard_bounds(cfg, shard)
        k1c = jnp.clip(k1, lo, hi + 1)
        k2c = jnp.clip(k2, lo - 1, hi)
        nonempty = (k1c <= k2c)
        keys, vals, counts, ok = range_runs(
            all_runs(cfg.local, st), k1c, k2c, max_candidates, max_results
        )
        counts = jnp.where(nonempty, counts, 0)
        ok = ok | ~nonempty
        ok = jax.lax.pmin(ok.astype(jnp.int32), cfg.axis) > 0
        return keys[None], vals[None], counts[None], ok[None]

    f = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(), P()),
        out_specs=(state_spec, state_spec, state_spec, P()),
        check_vma=False,
    )
    keys, vals, counts, ok = f(states, k1, k2)
    return keys, vals, counts, ok[0]


def assemble_range(keys, vals, counts, ok, max_results: int):
    """Shard-major range output -> the facade's global contract.

    keys/vals: [S, nq, m] per-shard compacted rows (ascending, placebo-padded
    past counts[s, q]); counts: [S, nq] exact per-shard hit counts; ok: [nq].
    Returns (keys [nq, max_results], vals, counts [nq], ok) with rows globally
    ascending (shards are range-ordered) and placebo-padded past counts[q].
    Truncation — global totals past max_results, or a shard that clipped its
    own window — flips ok, never silently drops.
    """
    S, nq, m = keys.shape
    offsets = jnp.cumsum(counts, axis=0) - counts       # exclusive, over shards
    total = jnp.sum(counts, axis=0).astype(jnp.int32)
    ok = ok & (total <= max_results)

    j = jnp.arange(m, dtype=jnp.int32)[None, None, :]
    valid = j < counts[:, :, None]
    tgt = jnp.where(valid, offsets[:, :, None] + j, max_results)  # OOB -> drop
    rows = jnp.broadcast_to(jnp.arange(nq, dtype=jnp.int32)[None, :, None], (S, nq, m))
    out_k = jnp.full((nq, max_results), sem.PLACEBO_KEY, jnp.int32)
    out_v = jnp.full((nq, max_results), sem.EMPTY_VALUE, jnp.int32)
    out_k = out_k.at[rows, tgt].set(keys, mode="drop")
    out_v = out_v.at[rows, tgt].set(vals, mode="drop")
    return out_k, out_v, total, ok


def dist_cleanup(cfg: DistLSMConfig, mesh, states) -> LSMState:
    """Shard-local cleanup — zero communication."""
    state_spec = P(cfg.axis)

    def body(states):
        return _restack(lsm_cleanup(cfg.local, _local_state(states)))

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=state_spec,
                  check_vma=False)
    return f(states)


def dist_maintain(
    cfg: DistLSMConfig,
    mesh,
    states,
    budget: int | None = None,
    *,
    only_if_debt: bool = False,
) -> LSMState:
    """Shard-local budgeted maintenance — zero communication, same as
    cleanup/flush. `budget` is the PER-SHARD element budget (static); shards
    carry independent debt (ownership skew), so each compacts — or skips, with
    only_if_debt — on its own schedule."""
    state_spec = P(cfg.axis)

    def body(states):
        return _restack(
            lsm_maintain(cfg.local, _local_state(states), budget,
                         only_if_debt=only_if_debt)
        )

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=state_spec,
                  check_vma=False)
    return f(states)


def dist_size(cfg: DistLSMConfig, mesh, states):
    """Live (visible) element count across all shards, int32 scalar.

    Shards own disjoint key ranges, so per-shard survivor counts simply add —
    no cross-shard dedup pass is ever needed.
    """
    state_spec = P(cfg.axis)

    def body(states):
        st = _local_state(states)
        local = valid_count_runs(all_runs(cfg.local, st))
        return jax.lax.psum(local, cfg.axis)

    f = shard_map(body, mesh=mesh, in_specs=(state_spec,), out_specs=P(),
                  check_vma=False)
    return f(states)


def dist_bulk_build(cfg: DistLSMConfig, mesh, keys, values) -> LSMState:
    """Build from n unique keys: each shard sorts its owned subset into the
    post-CLEANUP level layout (paper §5.2, per shard).

    The key set is replicated in; non-owned lanes become placebos, which sort
    last, so the owned prefix slices into levels exactly like a local bulk
    build of the owned subset. The per-shard resident-batch count r is a
    traced value (ownership skew is data-dependent), which `_redistribute`
    supports natively.
    """
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    n = keys.shape[0]
    cap = cfg.local.capacity
    if n > cap:
        raise ValueError(
            f"bulk build of {n} keys exceeds per-shard capacity {cap} "
            "(one shard may own every key)"
        )
    state_spec = P(cfg.axis)
    b = cfg.local.batch_size

    def body(keys, values):
        shard = jax.lax.axis_index(cfg.axis).astype(jnp.int32)
        mine = owner_of(cfg, keys) == shard
        kv = jnp.where(mine, sem.encode_insert(keys), sem.PLACEBO_KV)
        val = jnp.where(mine, values, sem.EMPTY_VALUE)
        kv, val = ops.sort_pairs(kv, val)
        owned = jnp.sum(mine).astype(jnp.int32)
        r_new = (owned + b - 1) // b
        pk, pv = _placebo(cap - n)
        kv = jnp.concatenate([kv, pk])
        val = jnp.concatenate([val, pv])
        kvs, vals = _redistribute(cfg.local, kv, val, r_new)
        st = LSMState(
            key_vars=kvs, values=vals, r=r_new,
            overflowed=jnp.zeros((), dtype=bool),
            lvl_debt=jnp.zeros((cfg.local.num_levels,), dtype=jnp.int32),
            **_fresh_buffer(b),
        )
        return _restack(st)

    f = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=state_spec,
                  check_vma=False)
    return f(keys, values)


# ---------------------------------------------------------------------------
# Standalone jitted factories (original surface; donation where mutating)
# ---------------------------------------------------------------------------


def make_dist_update(cfg: DistLSMConfig, mesh):
    """Returns jitted update(states, key_vars[b], values[b]) -> states."""
    return jax.jit(functools.partial(dist_update, cfg, mesh), donate_argnums=0)


def make_dist_lookup(cfg: DistLSMConfig, mesh):
    """Returns jitted lookup(states, keys[q]) -> (found[q], values[q])."""
    return jax.jit(functools.partial(dist_lookup, cfg, mesh))


def make_dist_count(cfg: DistLSMConfig, mesh, max_candidates: int):
    """Returns jitted count(states, k1[q], k2[q]) -> (counts[q], ok[q])."""
    return jax.jit(
        functools.partial(dist_count, cfg, mesh, max_candidates=max_candidates)
    )


def make_dist_range(cfg: DistLSMConfig, mesh, max_candidates: int, max_results: int):
    """Returns jitted shard-major range(states, k1[q], k2[q])."""
    return jax.jit(functools.partial(
        dist_range, cfg, mesh, max_candidates=max_candidates, max_results=max_results
    ))


def make_dist_cleanup(cfg: DistLSMConfig, mesh):
    """Shard-local cleanup — zero communication."""
    return jax.jit(functools.partial(dist_cleanup, cfg, mesh), donate_argnums=0)


def make_dist_maintain(cfg: DistLSMConfig, mesh, budget: int | None = None):
    """Returns jitted maintain(states) -> states (shard-local, zero comm)."""
    return jax.jit(
        functools.partial(dist_maintain, cfg, mesh, budget=budget),
        donate_argnums=0,
    )


def make_dist_stage(cfg: DistLSMConfig, mesh):
    """Returns jitted stage(states, key_vars[b], values[b], count) -> states."""
    return jax.jit(functools.partial(dist_stage, cfg, mesh), donate_argnums=0)


def make_dist_flush(cfg: DistLSMConfig, mesh):
    """Returns jitted flush(states) -> states (shard-local, zero comm)."""
    return jax.jit(functools.partial(dist_flush, cfg, mesh), donate_argnums=0)


def make_dist_size(cfg: DistLSMConfig, mesh):
    """Returns jitted size(states) -> int32 scalar (live elements, all shards)."""
    return jax.jit(functools.partial(dist_size, cfg, mesh))
