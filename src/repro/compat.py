"""Version-compat shims for JAX APIs that moved between releases.

The repo targets the current jax API surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``); older runtimes (<= 0.4.x) expose the same
functionality under ``jax.experimental.shard_map`` with ``check_rep`` and a
``make_mesh`` without axis types. Everything that touches these APIs imports
them from here so the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import enum

import jax

try:  # jax >= 0.6: top-level export, `check_vma` kwarg
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental module, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` with the replication-check kwarg normalized.

    `check_vma` (new name) and `check_rep` (old name) gate the same
    per-output replication verification; we accept the new name and forward
    to whichever the runtime understands.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check_vma}
    )


try:  # jax >= 0.5.x
    AxisType = jax.sharding.AxisType
    _HAS_AXIS_TYPES = True
except AttributeError:

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stub of jax.sharding.AxisType for runtimes that predate it."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPES = False


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """`jax.make_mesh` that tolerates runtimes without `axis_types`.

    On old jax every mesh axis is implicitly Auto, which is the only type
    this codebase requests — dropping the argument is semantics-preserving.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _HAS_AXIS_TYPES:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
