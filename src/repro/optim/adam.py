"""Hand-rolled sharded AdamW with cosine schedule, global-norm clipping, and
optional reduced-precision moments (needed to fit 671B optimizer state on a
16 GB/chip pod — see EXPERIMENTS.md §Dry-run).

Optimizer state shards exactly like the parameters (same tree structure), so
`params_shardings` applies verbatim — ZeRO-3 via GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32  # bf16 halves optimizer HBM for the 671B run


class AdamState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adam_init(cfg: AdamConfig, params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adam_update(cfg: AdamConfig, params, grads, state: AdamState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamState(new_m, new_v, step), metrics
