"""Pallas TPU kernels for the LSM hot-spots (+ pure-jnp oracles in ref.py)."""
