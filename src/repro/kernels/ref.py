"""Pure-jnp oracles for the LSM kernels.

These are the semantic ground truth for the Pallas kernels (merge_path,
bitonic_sort, lsm_lookup) and also serve as the XLA fallback path used on
platforms without Pallas support (e.g. this CPU container outside of
interpret-mode tests). Everything here is O(n log n) rank-based and fully
parallel, so the fallback is itself production-quality XLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import semantics as sem


def merge_ref(a_kv, a_val, b_kv, b_val):
    """Stable merge of two sorted runs, comparing ORIGINAL keys only.

    `a` is the NEWER run: for equal original keys, all of `a`'s elements
    precede all of `b`'s in the output (paper §4.1 — "new levels merged into
    existing levels appear first in the merged result"). Within each run the
    input order is preserved.

    Rank-based formulation: element a[i] lands at i + |{j : b_key[j] < a_key[i]}|,
    element b[j] lands at j + |{i : a_key[i] <= b_key[j]}|. Both scatters are
    disjoint and cover [0, |a|+|b|).
    """
    a_keys = sem.original_key(a_kv)
    b_keys = sem.original_key(b_kv)
    na, nb = a_keys.shape[0], b_keys.shape[0]
    idx_a = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(b_keys, a_keys, side="left").astype(jnp.int32)
    idx_b = jnp.arange(nb, dtype=jnp.int32) + jnp.searchsorted(a_keys, b_keys, side="right").astype(jnp.int32)
    out_kv = jnp.zeros(na + nb, dtype=a_kv.dtype)
    out_val = jnp.zeros(na + nb, dtype=a_val.dtype)
    out_kv = out_kv.at[idx_a].set(a_kv).at[idx_b].set(b_kv)
    out_val = out_val.at[idx_a].set(a_val).at[idx_b].set(b_val)
    return out_kv, out_val


def merge_cascade_ref(runs_kv, runs_val):
    """K-way stable newest-first merge as a left fold of pairwise merges.

    The pairwise merge is associative under the newest-first tie rule (the
    accumulated side is always the newer one), so the fold is element-for-
    element identical to a true K-way priority merge — this is the semantic
    oracle for `merge_path.merge_cascade_path`.
    """
    out_kv, out_val = runs_kv[0], runs_val[0]
    for kv, val in zip(runs_kv[1:], runs_val[1:]):
        out_kv, out_val = merge_ref(out_kv, out_val, kv, val)
    return out_kv, out_val


def fused_lookup_ref(flat_kv, flat_val, query_keys):
    """Oracle for the fused multi-run lookup kernel: first flat match wins.

    O(q * n) dense match matrix — test oracle only; the production XLA
    fallback for lookups is the per-run loop in core/queries.py (per-run
    searchsorted is O(q log n)).
    """
    flat_kv = jnp.asarray(flat_kv, jnp.int32)
    flat_val = jnp.asarray(flat_val, jnp.int32)
    query_keys = jnp.asarray(query_keys, jnp.int32)
    match = sem.original_key(flat_kv)[None, :] == query_keys[:, None]
    any_match = match.any(axis=1)
    first = jnp.argmax(match, axis=1)
    best_kv = jnp.where(any_match, flat_kv[first], sem.PLACEBO_KV)
    best_val = jnp.where(any_match, flat_val[first], sem.EMPTY_VALUE)
    return best_kv, best_val


def sort_ref(key_vars, values):
    """Sort a batch by FULL key variable (status bit included), stable.

    Sorting by the full key variable puts a tombstone for key k before any
    regular element with key k from the same batch (paper §4.1), which makes
    same-batch insert-then-delete resolve to "deleted" (semantics item 6).
    """
    return jax.lax.sort((key_vars, values), dimension=0, is_stable=True, num_keys=1)


def lower_bound_ref(sorted_orig_keys, query_keys):
    """Index of the first element >= query (std::lower_bound)."""
    return jnp.searchsorted(sorted_orig_keys, query_keys, side="left").astype(jnp.int32)


def upper_bound_ref(sorted_orig_keys, query_keys):
    return jnp.searchsorted(sorted_orig_keys, query_keys, side="right").astype(jnp.int32)


def lookup_level_ref(level_kv, level_val, query_keys):
    """One level of the LSM lookup: lower-bound search + match/status check.

    Returns (hit, is_tomb, value): hit marks queries whose lower-bound element
    has a matching original key; is_tomb marks hits that are tombstones
    (resolve to "deleted"); value is the payload for regular hits.
    """
    orig = sem.original_key(level_kv)
    idx = jnp.searchsorted(orig, query_keys, side="left").astype(jnp.int32)
    idx_c = jnp.clip(idx, 0, level_kv.shape[0] - 1)
    found_kv = level_kv[idx_c]
    found_val = level_val[idx_c]
    in_range = idx < level_kv.shape[0]
    hit = in_range & (sem.original_key(found_kv) == query_keys)
    is_tomb = sem.is_tombstone(found_kv)
    return hit, is_tomb, found_val
