"""Streamed lower-bound Pallas kernel — the LSM query hot-spot.

The paper's lookup bottleneck is random memory access during per-thread binary
search (§4.2). A literal port would issue data-dependent HBM gathers — the
single worst access pattern on TPU. The TPU-native reformulation:

    lower_bound(level, q) == #elements of `level` with key < q
                          == sum over chunks of per-chunk counts.

So instead of one pointer-chasing search per query, we *stream* the level
through VMEM in LEVEL_CHUNK tiles (perfectly coalesced, bandwidth-bound) and
accumulate per-chunk counts for a whole block of queries at once. The
per-chunk count is an all-pairs comparison matrix — [QUERY_BLOCK x
LEVEL_CHUNK] int ops per LEVEL_CHUNK loads, which the VPU retires faster than
HBM can feed the keys, i.e. the kernel stays memory-bound (the roofline
optimum for a search over data that is read once).

Grid = (query tiles, level chunks); the output tile is revisited across the
chunk axis (standard Pallas accumulator pattern, initialized at chunk 0).

Fused multi-run lookup (`fused_lookup_runs`)
--------------------------------------------
The paper's retrieval trade-off is that every LOOKUP must consult *every* run.
The per-run formulation above pays that cost as one kernel launch (and one
full output round trip) per run. The fused kernel collapses the whole read
path into ONE `pallas_call` per query block: the runs are concatenated
newest-first into a single flat (key_var, value) array and *streamed* through
VMEM with manually double-buffered DMA (`pltpu.make_async_copy` over a
`FUSED_DEPTH`-deep revolving scratch), so the next chunk is in flight while
the VPU scans the current one.

Correctness rests on one observation: with runs concatenated newest-first
(write buffer, then level 0..L-1) every run is sorted with the newest element
first within equal keys, so the winning element for query q — the one the
per-run resolution loop would report — is exactly the matching element with
the LOWEST flat index. Run boundaries therefore never matter inside the
kernel: it tracks "first match so far" per query and the chunk loop visits
flat indices in ascending order. A tombstone (or placebo) match resolves the
query without reporting it found, which falls out of returning the matched
key_var itself and letting the caller decode status bits.

The defaults below (FUSED_CHUNK / FUSED_DEPTH) come from the
`benchmarks/kernel_bench.py` block-size x buffer-depth sweep plus v5e DMA
arithmetic: chunk=1024 moves 8KB per DMA row (large enough to amortize DMA
issue, small enough that (depth, 2, chunk) VMEM scratch stays tiny), and
depth=2 is the minimum that overlaps the chunk-c compare with the chunk-c+1
copy. NOTE the sweep's CPU interpret-mode wall clock prefers smaller chunks
and depth=1 — interpreted DMA does not overlap anything, so per-chunk
interpreter overhead dominates there; the sweep records both (the winner row
flags the drift) and the defaults follow the hardware reasoning until a real
TPU run re-picks them (see BENCH_kernels.json / ROADMAP open item).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import semantics as sem

QUERY_BLOCK = 256
LEVEL_CHUNK = 2048

# Fused multi-run kernel tile geometry (see module docstring for how these
# were picked; kernel_bench re-records the sweep every run).
FUSED_QUERY_BLOCK = 256
FUSED_CHUNK = 1024
FUSED_DEPTH = 2


def _lower_bound_kernel(q_ref, chunk_ref, o_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]          # [QUERY_BLOCK]
    keys = chunk_ref[...]   # [LEVEL_CHUNK]
    cnt = jnp.sum((keys[None, :] < q[:, None]).astype(jnp.int32), axis=1)
    o_ref[...] += cnt


def lower_bound_streamed(sorted_keys, query_keys, *, interpret=False):
    """Vectorized lower_bound over a sorted array (original keys).

    sorted_keys: int32[n], n % LEVEL_CHUNK == 0 (placebo-padded by the LSM).
    query_keys:  int32[q], q % QUERY_BLOCK == 0.
    """
    n = sorted_keys.shape[0]
    q = query_keys.shape[0]
    assert n % LEVEL_CHUNK == 0 and q % QUERY_BLOCK == 0, (n, q)
    grid = (q // QUERY_BLOCK, n // LEVEL_CHUNK)
    return pl.pallas_call(
        _lower_bound_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QUERY_BLOCK,), lambda i, c: (i,)),
            pl.BlockSpec((LEVEL_CHUNK,), lambda i, c: (c,)),
        ],
        out_specs=pl.BlockSpec((QUERY_BLOCK,), lambda i, c: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(query_keys.astype(jnp.int32), sorted_keys.astype(jnp.int32))


def _fused_lookup_kernel(q_ref, flat_hbm, okv_ref, oval_ref, *, n, chunk, depth):
    """One query tile vs the whole flat run array, streamed chunk by chunk.

    flat_hbm stays in HBM (memory_space=ANY); `depth` revolving VMEM buffers
    overlap the DMA of chunk c+depth with the scan of chunk c. Per chunk the
    scan is an all-pairs match matrix + first-match one-hot select — pure VPU
    work against data that is read exactly once, so the kernel is
    bandwidth-bound like the streamed lower_bound above, but issues ONE kernel
    for all runs instead of one per run.
    """
    num_chunks = n // chunk
    q = q_ref[...]                      # [query_block]
    qb = q.shape[0]

    def body(bufs, sems):
        def dma(c, slot):
            return pltpu.make_async_copy(
                flat_hbm.at[:, pl.ds(c * chunk, chunk)],
                bufs.at[slot],
                sems.at[slot],
            )

        for s in range(min(depth, num_chunks)):
            dma(s, s).start()

        def step(c, carry):
            best_kv, best_val = carry
            slot = jax.lax.rem(c, depth)
            dma(c, slot).wait()
            ckv = bufs[slot, 0, :]
            cval = bufs[slot, 1, :]
            keys = ckv >> 1             # original keys (placebos stay maximal)
            match = keys[None, :] == q[:, None]                      # [qb, chunk]
            first = match & (jnp.cumsum(match.astype(jnp.int32), axis=1) == 1)
            hit = jnp.sum(first.astype(jnp.int32), axis=1) > 0
            sel_kv = jnp.sum(jnp.where(first, ckv[None, :], 0), axis=1)
            sel_val = jnp.sum(jnp.where(first, cval[None, :], 0), axis=1)
            # A query is unresolved while its best is still the placebo
            # sentinel: no real element ever encodes to PLACEBO_KV (user keys
            # are < PLACEBO_KEY), and a legitimate placebo "match" (query ==
            # PLACEBO_KEY) leaves the sentinel in place, which decodes to the
            # same resolved-as-deleted answer.
            upd = hit & (best_kv == sem.PLACEBO_KV)
            best_kv = jnp.where(upd, sel_kv, best_kv)
            best_val = jnp.where(upd, sel_val, best_val)
            nxt = c + depth

            @pl.when(nxt < num_chunks)
            def _():
                dma(nxt, slot).start()

            return best_kv, best_val

        init = (
            jnp.full((qb,), sem.PLACEBO_KV, dtype=jnp.int32),
            jnp.full((qb,), sem.EMPTY_VALUE, dtype=jnp.int32),
        )
        best_kv, best_val = jax.lax.fori_loop(0, num_chunks, step, init)
        okv_ref[...] = best_kv
        oval_ref[...] = best_val

    pl.run_scoped(
        body,
        bufs=pltpu.VMEM((depth, 2, chunk), jnp.int32),
        sems=pltpu.SemaphoreType.DMA((depth,)),
    )


def fused_lookup_runs(
    flat_kv,
    flat_val,
    query_keys,
    *,
    chunk: int | None = None,
    query_block: int | None = None,
    depth: int | None = None,
    interpret: bool = False,
):
    """Fused multi-run LOOKUP: first flat match per query, one pallas_call.

    flat_kv/flat_val: int32[n] — all runs concatenated newest-first (write
      buffer, then levels), placebo-padded so n % chunk == 0.
    query_keys: int32[q], q % query_block == 0.
    Returns (best_kv, best_val): the winning element per query (PLACEBO_KV /
    EMPTY_VALUE when no run matches). Callers decode found/tombstone from the
    key variable — see `ops.lookup_runs_fused`.
    """
    chunk = FUSED_CHUNK if chunk is None else chunk
    query_block = FUSED_QUERY_BLOCK if query_block is None else query_block
    depth = FUSED_DEPTH if depth is None else depth
    n = flat_kv.shape[0]
    q = query_keys.shape[0]
    assert n % chunk == 0 and q % query_block == 0, (n, q, chunk, query_block)
    assert depth >= 1
    flat = jnp.stack(
        [jnp.asarray(flat_kv, jnp.int32), jnp.asarray(flat_val, jnp.int32)]
    )  # [2, n] — one DMA moves the kv and value rows of a chunk together
    grid = (q // query_block,)
    return pl.pallas_call(
        functools.partial(_fused_lookup_kernel, n=n, chunk=chunk, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((query_block,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # streamed manually via DMA
        ],
        out_specs=[
            pl.BlockSpec((query_block,), lambda i: (i,)),
            pl.BlockSpec((query_block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(query_keys.astype(jnp.int32), flat)
