"""Streamed lower-bound Pallas kernel — the LSM query hot-spot.

The paper's lookup bottleneck is random memory access during per-thread binary
search (§4.2). A literal port would issue data-dependent HBM gathers — the
single worst access pattern on TPU. The TPU-native reformulation:

    lower_bound(level, q) == #elements of `level` with key < q
                          == sum over chunks of per-chunk counts.

So instead of one pointer-chasing search per query, we *stream* the level
through VMEM in LEVEL_CHUNK tiles (perfectly coalesced, bandwidth-bound) and
accumulate per-chunk counts for a whole block of queries at once. The
per-chunk count is an all-pairs comparison matrix — [QUERY_BLOCK x
LEVEL_CHUNK] int ops per LEVEL_CHUNK loads, which the VPU retires faster than
HBM can feed the keys, i.e. the kernel stays memory-bound (the roofline
optimum for a search over data that is read once).

Grid = (query tiles, level chunks); the output tile is revisited across the
chunk axis (standard Pallas accumulator pattern, initialized at chunk 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QUERY_BLOCK = 256
LEVEL_CHUNK = 2048


def _lower_bound_kernel(q_ref, chunk_ref, o_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]          # [QUERY_BLOCK]
    keys = chunk_ref[...]   # [LEVEL_CHUNK]
    cnt = jnp.sum((keys[None, :] < q[:, None]).astype(jnp.int32), axis=1)
    o_ref[...] += cnt


def lower_bound_streamed(sorted_keys, query_keys, *, interpret=False):
    """Vectorized lower_bound over a sorted array (original keys).

    sorted_keys: int32[n], n % LEVEL_CHUNK == 0 (placebo-padded by the LSM).
    query_keys:  int32[q], q % QUERY_BLOCK == 0.
    """
    n = sorted_keys.shape[0]
    q = query_keys.shape[0]
    assert n % LEVEL_CHUNK == 0 and q % QUERY_BLOCK == 0, (n, q)
    grid = (q // QUERY_BLOCK, n // LEVEL_CHUNK)
    return pl.pallas_call(
        _lower_bound_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((QUERY_BLOCK,), lambda i, c: (i,)),
            pl.BlockSpec((LEVEL_CHUNK,), lambda i, c: (c,)),
        ],
        out_specs=pl.BlockSpec((QUERY_BLOCK,), lambda i, c: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(query_keys.astype(jnp.int32), sorted_keys.astype(jnp.int32))
