"""jit'd dispatch wrappers for the LSM compute hot-spots.

Backends:
  "xla"    — the pure-jnp reference implementations (kernels/ref.py). This is
             the default off-TPU: rank-based merge and `lax.sort` are already
             near-roofline XLA programs on CPU, and identical semantics.
  "pallas" — the Pallas TPU kernels (merge_path / bitonic_sort / lsm_lookup)
             with explicit BlockSpec VMEM tiling. On non-TPU platforms the
             kernels execute in interpret mode (used by the test suite to
             validate the kernel bodies against the oracles).

Selection: `set_backend(...)` or the REPRO_KERNEL_BACKEND env var.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")
# Pallas kernels run in interpret mode automatically off-TPU.
_INTERPRET = jax.default_backend() != "tpu"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("xla", "pallas"):
        raise ValueError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _pallas_viable_merge(na: int, nb: int) -> bool:
    from repro.kernels import merge_path

    return (
        na % merge_path.BLOCK == 0
        and nb % merge_path.BLOCK == 0
        and na >= merge_path.BLOCK
        and nb >= merge_path.BLOCK
    )


def merge_sorted(a_kv, a_val, b_kv, b_val):
    """Stable original-key merge; `a` is the newer run (ties: a first)."""
    if _BACKEND == "pallas" and _pallas_viable_merge(a_kv.shape[0], b_kv.shape[0]):
        from repro.kernels import merge_path

        return merge_path.merge_path(a_kv, a_val, b_kv, b_val, interpret=_INTERPRET)
    return ref.merge_ref(a_kv, a_val, b_kv, b_val)


def merge_cascade(runs):
    """K-way stable merge of sorted runs ordered NEWEST FIRST.

    runs: [(key_vars, values), ...]; ties on original key resolve to the
    earliest (newest) run, within a run to the earliest index — identical to
    a left fold of `merge_sorted` with the accumulated side as `a`.

    One binary-counter cascade step, a cleanup, and `valid_count_runs` are all
    K-way merges; on the Pallas backend they stream every element through VMEM
    exactly once (`merge_path.merge_cascade_path`) instead of paying one HBM
    round trip of the growing intermediate per fold step.
    """
    runs = [(jnp.asarray(kv, jnp.int32), jnp.asarray(v, jnp.int32)) for kv, v in runs]
    if len(runs) == 1:
        return runs[0]
    if _BACKEND == "pallas":
        from repro.kernels import merge_path

        if all(
            kv.shape[0] % merge_path.BLOCK == 0 and kv.shape[0] >= merge_path.BLOCK
            for kv, _ in runs
        ):
            return merge_path.merge_cascade_path(
                [kv for kv, _ in runs], [v for _, v in runs], interpret=_INTERPRET
            )
    # XLA fold (pairwise merges may still pick the pairwise Pallas kernel).
    out_kv, out_val = runs[0]
    for kv, val in runs[1:]:
        out_kv, out_val = merge_sorted(out_kv, out_val, kv, val)
    return out_kv, out_val


def sort_pairs(key_vars, values):
    """Sort (key_var, value) pairs by full key variable, stable."""
    if _BACKEND == "pallas":
        from repro.kernels import bitonic_sort

        n = key_vars.shape[0]
        if n >= bitonic_sort.MIN_N and (n & (n - 1)) == 0:
            return bitonic_sort.bitonic_sort_pairs(key_vars, values, interpret=_INTERPRET)
    return ref.sort_ref(key_vars, values)


def sort_pairs_recency(key_vars, values):
    """Sort by ORIGINAL key; within equal keys the later input lane sorts
    first (newest-first), regardless of status bit.

    This is the write-buffer batch-formation rule (docs/DESIGN.md §5): strict
    arrival order decides duplicates, unlike `sort_pairs`, whose full-key-
    variable ordering makes a tombstone beat any same-batch insert of its key
    (the paper's in-batch rule). Placebos sort last (maximum original key).
    """
    from repro.core import semantics as sem

    n = key_vars.shape[0]
    key_vars = jnp.asarray(key_vars, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    orig = sem.original_key(key_vars)
    rev = jnp.arange(n, 0, -1, dtype=jnp.int32)  # later lane -> smaller rev
    _, _, out_kv, out_val = jax.lax.sort(
        (orig, rev, key_vars, values), dimension=0, is_stable=True, num_keys=2
    )
    return out_kv, out_val


def lower_bound(sorted_orig_keys, query_keys):
    """Vectorized lower-bound (first index with key >= query)."""
    if _BACKEND == "pallas":
        from repro.kernels import lsm_lookup

        n, q = sorted_orig_keys.shape[0], query_keys.shape[0]
        if n % lsm_lookup.LEVEL_CHUNK == 0 and q % lsm_lookup.QUERY_BLOCK == 0:
            return lsm_lookup.lower_bound_streamed(
                sorted_orig_keys, query_keys, interpret=_INTERPRET
            )
    return ref.lower_bound_ref(sorted_orig_keys, query_keys)


def upper_bound(sorted_orig_keys, query_keys):
    """Vectorized upper-bound (first index with key > query).

    For integer keys, upper_bound(k) == lower_bound(k + 1), so the streamed
    Pallas lower-bound kernel accelerates both ends of the count/range
    window. Guard: k + 1 would wrap at INT32_MAX, but every key the structure
    can store (user keys plus the placebo key, all < 2**30) compares <= such
    a query, so the answer is simply n.
    """
    if _BACKEND == "pallas":
        from repro.kernels import lsm_lookup

        n, q = sorted_orig_keys.shape[0], query_keys.shape[0]
        if n % lsm_lookup.LEVEL_CHUNK == 0 and q % lsm_lookup.QUERY_BLOCK == 0:
            qk = jnp.asarray(query_keys, jnp.int32)
            safe = qk < jnp.iinfo(jnp.int32).max
            lo = lsm_lookup.lower_bound_streamed(
                sorted_orig_keys, jnp.where(safe, qk + 1, qk), interpret=_INTERPRET
            )
            return jnp.where(safe, lo, jnp.asarray(n, jnp.int32))
    return ref.upper_bound_ref(sorted_orig_keys, query_keys)


def lookup_runs_fused(runs, query_keys):
    """Fused multi-run LOOKUP dispatch: (found, values) or None.

    Selected on the Pallas backend: concatenates the newest-first runs into
    one flat array (placebo-padded to the chunk size), pads the queries to the
    query-block size, and issues ONE fused streaming kernel instead of one
    `lower_bound` launch per run (`lsm_lookup.fused_lookup_runs`). Returns
    None when not selected — the caller (core/queries.py::lookup_runs) falls
    back to the per-run resolution loop.
    """
    if _BACKEND != "pallas":
        return None
    from repro.core import semantics as sem
    from repro.kernels import lsm_lookup

    chunk = lsm_lookup.FUSED_CHUNK
    qb = lsm_lookup.FUSED_QUERY_BLOCK
    flat_kv = jnp.concatenate([jnp.asarray(kv, jnp.int32) for kv, _ in runs])
    flat_val = jnp.concatenate([jnp.asarray(v, jnp.int32) for _, v in runs])
    pad_n = -flat_kv.shape[0] % chunk
    if pad_n:
        flat_kv = jnp.concatenate([flat_kv, jnp.full((pad_n,), sem.PLACEBO_KV, jnp.int32)])
        flat_val = jnp.concatenate([flat_val, jnp.full((pad_n,), sem.EMPTY_VALUE, jnp.int32)])
    qk = jnp.asarray(query_keys, jnp.int32)
    nq = qk.shape[0]
    pad_q = -nq % qb
    qk_padded = jnp.concatenate([qk, jnp.full((pad_q,), sem.PLACEBO_KEY, jnp.int32)]) if pad_q else qk
    best_kv, best_val = lsm_lookup.fused_lookup_runs(
        flat_kv, flat_val, qk_padded, interpret=_INTERPRET
    )
    best_kv, best_val = best_kv[:nq], best_val[:nq]
    hit = sem.original_key(best_kv) == qk
    found = hit & ~sem.is_tombstone(best_kv)
    return found, jnp.where(found, best_val, sem.EMPTY_VALUE)


def lookup_level(level_kv, level_val, query_keys):
    """One-level lookup probe built on lower_bound (kernel-accelerated)."""
    from repro.core import semantics as sem

    orig = sem.original_key(level_kv)
    idx = lower_bound(orig, query_keys)
    idx_c = jnp.clip(idx, 0, level_kv.shape[0] - 1)
    found_kv = level_kv[idx_c]
    found_val = level_val[idx_c]
    in_range = idx < level_kv.shape[0]
    hit = in_range & (sem.original_key(found_kv) == query_keys)
    is_tomb = sem.is_tombstone(found_kv)
    return hit, is_tomb, found_val
