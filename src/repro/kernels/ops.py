"""jit'd dispatch wrappers for the LSM compute hot-spots.

Backends:
  "xla"    — the pure-jnp reference implementations (kernels/ref.py). This is
             the default off-TPU: rank-based merge and `lax.sort` are already
             near-roofline XLA programs on CPU, and identical semantics.
  "pallas" — the Pallas TPU kernels (merge_path / bitonic_sort / lsm_lookup)
             with explicit BlockSpec VMEM tiling. On non-TPU platforms the
             kernels execute in interpret mode (used by the test suite to
             validate the kernel bodies against the oracles).

Selection: `set_backend(...)` or the REPRO_KERNEL_BACKEND env var.
"""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "xla")
# Pallas kernels run in interpret mode automatically off-TPU.
_INTERPRET = jax.default_backend() != "tpu"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("xla", "pallas"):
        raise ValueError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _pallas_viable_merge(na: int, nb: int) -> bool:
    from repro.kernels import merge_path

    return (
        na % merge_path.BLOCK == 0
        and nb % merge_path.BLOCK == 0
        and na >= merge_path.BLOCK
        and nb >= merge_path.BLOCK
    )


def merge_sorted(a_kv, a_val, b_kv, b_val):
    """Stable original-key merge; `a` is the newer run (ties: a first)."""
    if _BACKEND == "pallas" and _pallas_viable_merge(a_kv.shape[0], b_kv.shape[0]):
        from repro.kernels import merge_path

        return merge_path.merge_path(a_kv, a_val, b_kv, b_val, interpret=_INTERPRET)
    return ref.merge_ref(a_kv, a_val, b_kv, b_val)


def sort_pairs(key_vars, values):
    """Sort (key_var, value) pairs by full key variable, stable."""
    if _BACKEND == "pallas":
        from repro.kernels import bitonic_sort

        n = key_vars.shape[0]
        if n >= bitonic_sort.MIN_N and (n & (n - 1)) == 0:
            return bitonic_sort.bitonic_sort_pairs(key_vars, values, interpret=_INTERPRET)
    return ref.sort_ref(key_vars, values)


def sort_pairs_recency(key_vars, values):
    """Sort by ORIGINAL key; within equal keys the later input lane sorts
    first (newest-first), regardless of status bit.

    This is the write-buffer batch-formation rule (docs/DESIGN.md §5): strict
    arrival order decides duplicates, unlike `sort_pairs`, whose full-key-
    variable ordering makes a tombstone beat any same-batch insert of its key
    (the paper's in-batch rule). Placebos sort last (maximum original key).
    """
    from repro.core import semantics as sem

    n = key_vars.shape[0]
    key_vars = jnp.asarray(key_vars, jnp.int32)
    values = jnp.asarray(values, jnp.int32)
    orig = sem.original_key(key_vars)
    rev = jnp.arange(n, 0, -1, dtype=jnp.int32)  # later lane -> smaller rev
    _, _, out_kv, out_val = jax.lax.sort(
        (orig, rev, key_vars, values), dimension=0, is_stable=True, num_keys=2
    )
    return out_kv, out_val


def lower_bound(sorted_orig_keys, query_keys):
    """Vectorized lower-bound (first index with key >= query)."""
    if _BACKEND == "pallas":
        from repro.kernels import lsm_lookup

        n, q = sorted_orig_keys.shape[0], query_keys.shape[0]
        if n % lsm_lookup.LEVEL_CHUNK == 0 and q % lsm_lookup.QUERY_BLOCK == 0:
            return lsm_lookup.lower_bound_streamed(
                sorted_orig_keys, query_keys, interpret=_INTERPRET
            )
    return ref.lower_bound_ref(sorted_orig_keys, query_keys)


def upper_bound(sorted_orig_keys, query_keys):
    """Vectorized upper-bound (first index with key > query).

    For integer keys, upper_bound(k) == lower_bound(k + 1), so the streamed
    Pallas lower-bound kernel accelerates both ends of the count/range
    window. Guard: k + 1 would wrap at INT32_MAX, but every key the structure
    can store (user keys plus the placebo key, all < 2**30) compares <= such
    a query, so the answer is simply n.
    """
    if _BACKEND == "pallas":
        from repro.kernels import lsm_lookup

        n, q = sorted_orig_keys.shape[0], query_keys.shape[0]
        if n % lsm_lookup.LEVEL_CHUNK == 0 and q % lsm_lookup.QUERY_BLOCK == 0:
            qk = jnp.asarray(query_keys, jnp.int32)
            safe = qk < jnp.iinfo(jnp.int32).max
            lo = lsm_lookup.lower_bound_streamed(
                sorted_orig_keys, jnp.where(safe, qk + 1, qk), interpret=_INTERPRET
            )
            return jnp.where(safe, lo, jnp.asarray(n, jnp.int32))
    return ref.upper_bound_ref(sorted_orig_keys, query_keys)


def lookup_level(level_kv, level_val, query_keys):
    """One-level lookup probe built on lower_bound (kernel-accelerated)."""
    from repro.core import semantics as sem

    orig = sem.original_key(level_kv)
    idx = lower_bound(orig, query_keys)
    idx_c = jnp.clip(idx, 0, level_kv.shape[0] - 1)
    found_kv = level_kv[idx_c]
    found_val = level_val[idx_c]
    in_range = idx < level_kv.shape[0]
    hit = in_range & (sem.original_key(found_kv) == query_keys)
    is_tomb = sem.is_tombstone(found_kv)
    return hit, is_tomb, found_val
