"""Bitonic sort Pallas kernel — the batch-sort hot-spot of LSM updates.

The paper uses CUB radix sort. Radix sort is scatter-heavy (per-pass bucket
scatters), which is hostile to the TPU's vector memory; the TPU-idiomatic
equivalent of "fast device sort of a VMEM-resident tile" is a bitonic
compare-exchange network: every stage is a branch-free reshape + min/max over
lanes — zero gathers, zero scatters, perfect for the 8x128 VPU.

The kernel sorts CHUNK-sized tiles entirely inside VMEM (grid over tiles).
Arbitrarily large batches are handled in ops.py by a hierarchical sort:
bitonic-sorted chunks are combined with the Merge-Path kernel in compare-full
mode — exactly the LSM trick, reused one level down.

Sorting compares the FULL 32-bit key variable (status bit included), so a
tombstone lands before the regular elements of its key within a batch, which
is what makes same-batch insert-then-delete resolve to "deleted" (§4.1).
Not stable among *identical* key variables (semantically immaterial: equal
key variable => same key and same status; which duplicate survives a lookup
is unspecified by semantics item 4).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 1 << 10          # elements sorted in one VMEM tile
MIN_N = 8
_INT32_MAX = jnp.iinfo(jnp.int32).max


def _compare_exchange(kv, val, j, k, n):
    """One bitonic stage: partner distance j within ascending-by-bit-k runs."""
    m = n // (2 * j)
    kv3 = kv.reshape(m, 2, j)
    val3 = val.reshape(m, 2, j)
    a_kv, b_kv = kv3[:, 0, :], kv3[:, 1, :]
    a_val, b_val = val3[:, 0, :], val3[:, 1, :]
    # Direction bit: ascending iff (flat_index & k) == 0; constant across the
    # pair (j < k), so evaluate it at the `a` element.
    flat_a = (
        jnp.arange(m, dtype=jnp.int32)[:, None] * (2 * j)
        + jnp.arange(j, dtype=jnp.int32)[None, :]
    )
    asc = (flat_a & k) == 0
    swap = (a_kv > b_kv) == asc  # out of order w.r.t. direction
    new_a_kv = jnp.where(swap, b_kv, a_kv)
    new_b_kv = jnp.where(swap, a_kv, b_kv)
    new_a_val = jnp.where(swap, b_val, a_val)
    new_b_val = jnp.where(swap, a_val, b_val)
    kv3 = jnp.stack([new_a_kv, new_b_kv], axis=1)
    val3 = jnp.stack([new_a_val, new_b_val], axis=1)
    return kv3.reshape(n), val3.reshape(n)


def _bitonic_kernel(x_ref, o_ref, *, n):
    kv = x_ref[0, :]
    val = x_ref[1, :]
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            kv, val = _compare_exchange(kv, val, j, k, n)
            j //= 2
        k *= 2
    o_ref[0, :] = kv
    o_ref[1, :] = val


def bitonic_sort_pairs(key_vars, values, *, interpret=False):
    """Sort (key_var, value) pairs by full key variable.

    n must be a power of two. n <= CHUNK sorts in a single VMEM tile;
    larger powers of two sort CHUNK tiles in parallel grid steps and are
    merged by the caller (ops.sort_pairs_hierarchical).
    """
    n = key_vars.shape[0]
    assert n & (n - 1) == 0 and n >= MIN_N, n
    tile = min(n, CHUNK)
    n_tiles = n // tile
    stacked = jnp.stack([key_vars.astype(jnp.int32), values.astype(jnp.int32)])
    out = pl.pallas_call(
        functools.partial(_bitonic_kernel, n=tile),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((2, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((2, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.int32),
        interpret=interpret,
    )(stacked)
    kv, val = out[0], out[1]
    if n_tiles > 1:
        from repro.kernels import merge_path

        # Hierarchical combine: pairwise compare-full Merge-Path rounds.
        runs = [(kv[i * tile : (i + 1) * tile], val[i * tile : (i + 1) * tile]) for i in range(n_tiles)]
        while len(runs) > 1:
            nxt = []
            for i in range(0, len(runs), 2):
                a, b = runs[i], runs[i + 1]
                nxt.append(
                    merge_path.merge_path(
                        a[0], a[1], b[0], b[1], compare_full=True, interpret=interpret
                    )
                )
            runs = nxt
        kv, val = runs[0]
    return kv, val
