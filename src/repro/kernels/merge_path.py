"""Merge Path Pallas kernel — the LSM's cascade-merge hot-spot on TPU.

The paper uses moderngpu's Merge Path merge (diagonal partition + per-CTA
shared-memory merges). The TPU adaptation:

  * The diagonal partition (one binary search per output tile boundary) is a
    tiny vectorized XLA computation (`merge_partition`) — T+1 searches of
    O(log n) each. Its result is handed to the kernel as a *scalar prefetch*
    operand, the TPU analogue of reading partition points from global memory
    before the CTA starts.
  * Each grid step merges one BLOCK-sized output tile. Its A/B windows are
    data-dependent, so the BlockSpec index maps are driven by the prefetched
    partition: each side fetches the two consecutive BLOCK-blocks that cover
    its (unaligned, <= BLOCK long) window — HBM→VMEM copies stay block-aligned
    and coalesced, and the unaligned window is carved out in-register.
  * The in-tile merge is rank-based and branch-free: an all-pairs comparison
    matrix (VPU-friendly, [BLOCK x BLOCK] int ops against ~BLOCK loads — the
    kernel stays bandwidth-bound for BLOCK <= 1024) yields each element's
    local rank; a local scatter materializes the tile. No serial merge loop,
    no divergence — this replaces the warp-wide serial merges of the CUDA
    version, which have no SIMD-lockstep analogue on the VPU.

Semantics match `ref.merge_ref`: compare original keys (status bit ignored),
stable, ties taken from `a` (the newer run) first. With `compare_full=True`
the comparison uses the full key variable instead — used by the hierarchical
large-batch sort in ops.py (sorted chunks + merge cascade).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256
_INT32_MAX = jnp.iinfo(jnp.int32).max


def merge_partition(a_keys, b_keys, diags):
    """Merge-Path split: #elements taken from `a` among the first d outputs.

    Ties go to `a` (take from `a` while a_key <= b_key). Vectorized binary
    search over all diagonals at once.
    """
    na, nb = a_keys.shape[0], b_keys.shape[0]
    diags = jnp.asarray(diags, jnp.int32)
    lo = jnp.maximum(0, diags - nb)
    hi = jnp.minimum(diags, na)
    steps = max(1, int(math.ceil(math.log2(max(na + nb, 2)))) + 1)
    for _ in range(steps):
        active = lo < hi
        mid = (lo + hi) // 2
        a_v = a_keys[jnp.clip(mid, 0, na - 1)]
        b_v = b_keys[jnp.clip(diags - 1 - mid, 0, nb - 1)]
        pred = a_v <= b_v  # can take one more from a
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
    return lo


def _window(buf2, start, block0, length, fill):
    """Carve an unaligned window [start, start+BLOCK) out of two fetched blocks.

    buf2: [2, 2*BLOCK] (kv row 0, val row 1) — two adjacent BLOCK-blocks.
    Lanes >= length are masked to `fill` (kv) / 0 (val).
    """
    shift = start - block0 * BLOCK
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    idx = jnp.clip(shift + lane, 0, 2 * BLOCK - 1)
    kv = jnp.take(buf2[0], idx)
    val = jnp.take(buf2[1], idx)
    valid = lane < length
    return jnp.where(valid, kv, fill), jnp.where(valid, val, 0), valid


def _merge_kernel(bounds_ref, a0_ref, a1_ref, b0_ref, b1_ref, o_ref, *, na, nb, shift):
    t = pl.program_id(0)
    d0 = t * BLOCK
    a_start = bounds_ref[t]
    a_end = bounds_ref[t + 1]
    b_start = d0 - a_start
    b_end = d0 + BLOCK - a_end
    la = a_end - a_start
    lb = b_end - b_start

    blk_a = jnp.minimum(a_start // BLOCK, na // BLOCK - 1)
    blk_b = jnp.minimum(b_start // BLOCK, nb // BLOCK - 1)
    abuf = jnp.concatenate([a0_ref[...], a1_ref[...]], axis=1)
    bbuf = jnp.concatenate([b0_ref[...], b1_ref[...]], axis=1)
    a_kv, a_val, _ = _window(abuf, a_start, blk_a, la, _INT32_MAX)
    b_kv, b_val, _ = _window(bbuf, b_start, blk_b, lb, _INT32_MAX)

    # Comparison keys: original key (>> 1) or full key variable. Invalid lanes
    # already hold INT32_MAX, whose shifted form still dominates every valid key.
    a_cmp = a_kv >> shift if shift else a_kv
    b_cmp = b_kv >> shift if shift else b_kv
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    a_cmp = jnp.where(lane < la, a_cmp, _INT32_MAX)
    b_cmp = jnp.where(lane < lb, b_cmp, _INT32_MAX)

    # All-pairs ranks: a[i] precedes b[j] iff a_cmp[i] <= b_cmp[j].
    rank_a = lane + jnp.sum((b_cmp[None, :] < a_cmp[:, None]).astype(jnp.int32), axis=1)
    rank_b = lane + jnp.sum((a_cmp[None, :] <= b_cmp[:, None]).astype(jnp.int32), axis=1)

    out_kv = jnp.zeros((BLOCK,), jnp.int32)
    out_val = jnp.zeros((BLOCK,), jnp.int32)
    out_kv = out_kv.at[rank_a].set(a_kv, mode="drop").at[rank_b].set(b_kv, mode="drop")
    out_val = out_val.at[rank_a].set(a_val, mode="drop").at[rank_b].set(b_val, mode="drop")
    o_ref[0, :] = out_kv
    o_ref[1, :] = out_val


def merge_path(a_kv, a_val, b_kv, b_val, *, compare_full=False, interpret=False):
    """Merge two sorted runs (a = newer). Shapes must be multiples of BLOCK."""
    na, nb = a_kv.shape[0], b_kv.shape[0]
    n = na + nb
    assert na % BLOCK == 0 and nb % BLOCK == 0, (na, nb)
    shift = 0 if compare_full else 1
    a_keys = (a_kv >> shift) if shift else a_kv
    b_keys = (b_kv >> shift) if shift else b_kv
    n_tiles = n // BLOCK
    diags = jnp.arange(n_tiles + 1, dtype=jnp.int32) * BLOCK
    bounds = merge_partition(a_keys, b_keys, diags).astype(jnp.int32)

    a_stack = jnp.stack([a_kv, a_val])  # [2, na]
    b_stack = jnp.stack([b_kv, b_val])

    na_blocks = na // BLOCK
    nb_blocks = nb // BLOCK

    def a_idx0(t, bounds):
        return (0, jnp.minimum(bounds[t] // BLOCK, na_blocks - 1))

    def a_idx1(t, bounds):
        return (0, jnp.minimum(bounds[t] // BLOCK + 1, na_blocks - 1))

    def b_idx0(t, bounds):
        return (0, jnp.minimum((t * BLOCK - bounds[t]) // BLOCK, nb_blocks - 1))

    def b_idx1(t, bounds):
        return (0, jnp.minimum((t * BLOCK - bounds[t]) // BLOCK + 1, nb_blocks - 1))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((2, BLOCK), a_idx0),
            pl.BlockSpec((2, BLOCK), a_idx1),
            pl.BlockSpec((2, BLOCK), b_idx0),
            pl.BlockSpec((2, BLOCK), b_idx1),
        ],
        out_specs=pl.BlockSpec((2, BLOCK), lambda t, bounds: (0, t)),
    )
    out = pl.pallas_call(
        functools.partial(_merge_kernel, na=na, nb=nb, shift=shift),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.int32),
        interpret=interpret,
    )(bounds, a_stack, a_stack, b_stack, b_stack)
    return out[0], out[1]


# ---------------------------------------------------------------------------
# K-way cascade merge: stream K runs through VMEM in one pallas_call
# ---------------------------------------------------------------------------
#
# A binary-counter cascade step merges the carry batch with levels 0..j-1 —
# previously a CHAIN of pairwise merge_path calls, each round-tripping the
# growing intermediate through HBM (the carry is written and re-read j times).
# The K-way kernel generalizes Merge Path: the diagonal partition becomes a
# *key-space* binary search (`cascade_partition`) that splits ALL K runs at
# every output-tile boundary simultaneously, and each grid step merges its K
# windows in VMEM with K-1 rank-based all-pairs merges. Every input element
# crosses HBM exactly once, regardless of K.


def cascade_partition(runs_keys, diags):
    """K-way Merge-Path split: bounds[s, d] = #elements of run s among the
    first diags[d] outputs of the K-way merge.

    Runs are ordered newest first; ties on the comparison key resolve by run
    order (earlier run first), and within a run by index — identical to a
    left fold of pairwise merges with the accumulated (newer) side winning
    ties, which is what `ref.merge_cascade_ref` computes.

    Instead of searching each diagonal's simplex directly (K-dimensional), we
    binary-search the KEY SPACE: for diagonal d find the smallest key k* with
    N_leq(k*) >= d (31 halvings over the int32 key domain, each a vectorized
    searchsorted per run over all diagonals at once). The first d outputs are
    then all elements with key < k*, plus t = d - N_less(k*) elements of the
    key == k* segments taken in run order.
    """
    diags = jnp.asarray(diags, jnp.int32)
    lo = jnp.zeros_like(diags)
    hi = jnp.full_like(diags, _INT32_MAX)
    for _ in range(31):
        mid = lo + (hi - lo) // 2
        n_leq = sum(
            jnp.searchsorted(ks, mid, side="right").astype(jnp.int32)
            for ks in runs_keys
        )
        pred = n_leq >= diags
        hi = jnp.where(pred, mid, hi)
        lo = jnp.where(pred, lo, mid + 1)
    kstar = lo  # d == 0 degenerates to kstar == 0, bounds 0 (keys are >= 0)
    lbs = [jnp.searchsorted(ks, kstar, side="left").astype(jnp.int32) for ks in runs_keys]
    ubs = [jnp.searchsorted(ks, kstar, side="right").astype(jnp.int32) for ks in runs_keys]
    n_less = sum(lbs)
    t = diags - n_less  # elements still needed from the key == k* segments
    bounds = []
    prefix = jnp.zeros_like(diags)
    for lb, ub in zip(lbs, ubs):
        seg = ub - lb
        bounds.append(lb + jnp.clip(t - prefix, 0, seg))
        prefix = prefix + seg
    return jnp.stack(bounds)  # [K, len(diags)]


def _cascade_kernel(bounds_ref, *refs, ns, shift):
    """Merge one BLOCK-wide output tile from K run windows.

    refs: 2 fetched blocks per run (adjacent BLOCK-blocks covering its
    window), then the output ref. The K windows (total length exactly BLOCK)
    fold left-to-right with the same rank-based all-pairs merge as
    `_merge_kernel`; the accumulated side is the newer one (earlier runs), so
    it takes ties with `<=`. Lanes beyond each side's valid length carry
    _INT32_MAX comparison keys: their ranks land at or beyond the combined
    valid length (accumulated side) or beyond BLOCK entirely (window side), so
    they never corrupt valid output lanes.
    """
    o_ref = refs[-1]
    t = pl.program_id(0)
    lane = jnp.arange(BLOCK, dtype=jnp.int32)
    acc_kv = acc_val = acc_len = None
    for s in range(len(ns)):
        start = bounds_ref[s, t]
        ln = bounds_ref[s, t + 1] - start
        blk = jnp.minimum(start // BLOCK, ns[s] // BLOCK - 1)
        buf = jnp.concatenate([refs[2 * s][...], refs[2 * s + 1][...]], axis=1)
        kv, val, _ = _window(buf, start, blk, ln, _INT32_MAX)
        cmp = kv >> shift if shift else kv
        cmp = jnp.where(lane < ln, cmp, _INT32_MAX)
        if acc_kv is None:
            acc_kv, acc_val, acc_len = kv, val, ln
            continue
        acc_cmp = acc_kv >> shift if shift else acc_kv
        acc_cmp = jnp.where(lane < acc_len, acc_cmp, _INT32_MAX)
        rank_a = lane + jnp.sum((cmp[None, :] < acc_cmp[:, None]).astype(jnp.int32), axis=1)
        rank_b = lane + jnp.sum((acc_cmp[None, :] <= cmp[:, None]).astype(jnp.int32), axis=1)
        new_kv = jnp.zeros((BLOCK,), jnp.int32)
        new_val = jnp.zeros((BLOCK,), jnp.int32)
        acc_kv = new_kv.at[rank_a].set(acc_kv, mode="drop").at[rank_b].set(kv, mode="drop")
        acc_val = new_val.at[rank_a].set(acc_val, mode="drop").at[rank_b].set(val, mode="drop")
        acc_len = acc_len + ln
    o_ref[0, :] = acc_kv
    o_ref[1, :] = acc_val


def merge_cascade_path(runs_kv, runs_val, *, compare_full=False, interpret=False):
    """K-way merge of sorted runs, newest first. Lengths multiples of BLOCK.

    Semantics match a left fold of `merge_path` (equivalently
    `ref.merge_cascade_ref`), but each element crosses HBM once instead of
    once per fold step.
    """
    k = len(runs_kv)
    assert k >= 1 and len(runs_val) == k
    if k == 1:
        return runs_kv[0], runs_val[0]
    ns = [kv.shape[0] for kv in runs_kv]
    assert all(n % BLOCK == 0 for n in ns), ns
    total = sum(ns)
    n_tiles = total // BLOCK
    shift = 0 if compare_full else 1
    run_keys = [(kv >> shift) if shift else kv for kv in runs_kv]
    diags = jnp.arange(n_tiles + 1, dtype=jnp.int32) * BLOCK
    bounds = cascade_partition(run_keys, diags)  # [K, n_tiles + 1]

    stacks = [jnp.stack([kv, val]) for kv, val in zip(runs_kv, runs_val)]

    def make_idx(s, delta, nblocks):
        def idx(t, bounds):
            return (0, jnp.minimum(bounds[s, t] // BLOCK + delta, nblocks - 1))

        return idx

    in_specs = []
    operands = []
    for s in range(k):
        nblocks = ns[s] // BLOCK
        in_specs.append(pl.BlockSpec((2, BLOCK), make_idx(s, 0, nblocks)))
        in_specs.append(pl.BlockSpec((2, BLOCK), make_idx(s, 1, nblocks)))
        operands.extend([stacks[s], stacks[s]])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((2, BLOCK), lambda t, bounds: (0, t)),
    )
    out = pl.pallas_call(
        functools.partial(_cascade_kernel, ns=tuple(ns), shift=shift),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((2, total), jnp.int32),
        interpret=interpret,
    )(bounds, *operands)
    return out[0], out[1]
