"""Cross-backend differential parity: lsm / sorted_array / lsm_sharded.

Every backend with the full capability row must be *the same dictionary*
behind the facade: identical lookup / size / count / range answers (down to
range-row placebo padding) on randomized op sequences with duplicate keys,
tombstone churn, and boundary keys at 0 / MAX_USER_KEY / shard boundaries —
all checked against a Python-dict oracle that models the facade's chunk
semantics exactly (tests/harness.py).

The sharded backend runs at 1 / 2 / 4 shards on spoofed CPU devices
(conftest forces --xla_force_host_platform_device_count=4 before jax
initializes; CI additionally runs this file in a dedicated multi-device
job). Hypothesis variants of the same harness are marked `slow` and skip
when hypothesis is not installed.
"""

import jax
import numpy as np
import pytest

from repro.api import Dictionary, QueryPlan
from repro.core import semantics as sem

from harness import (
    boundary_keys,
    gen_ops,
    key_pool,
    query_ranges,
    range_size,
    run_differential,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is a dev-only dep; the seeded tests still run
    HAVE_HYPOTHESIS = False

B = 8
NUM_LEVELS = 6  # capacity 8 * 63 = 504 for every run-based backend
CAPACITY = B * ((1 << NUM_LEVELS) - 1)
PLAN = QueryPlan(max_candidates=CAPACITY, max_results=64)


def _needs_devices(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n, reason=f"needs {n} (forced) host devices"
    )


SHARD_PARAMS = [
    pytest.param(1, id="shards1"),
    pytest.param(2, marks=_needs_devices(2), id="shards2"),
    pytest.param(4, marks=_needs_devices(4), id="shards4"),
]


def _make_backends(num_shards):
    return {
        "lsm": Dictionary.create("lsm", batch_size=B, num_levels=NUM_LEVELS),
        "sorted_array": Dictionary.create(
            "sorted_array", batch_size=B, capacity=CAPACITY
        ),
        f"lsm_sharded@{num_shards}": Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ),
    }


def _queries(pool):
    qs = np.concatenate([pool, np.clip(pool + 1, 0, sem.MAX_USER_KEY)])
    return np.unique(qs)


class TestDifferentialParity:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_randomized_sequences(self, seed, num_shards):
        rng = np.random.default_rng(seed)
        pool = key_pool(rng)
        ops = gen_ops(rng, pool, n_steps=8, batch_size=B)
        k1, k2 = query_ranges(pool)
        run_differential(
            _make_backends(num_shards), ops,
            batch_size=B, plan=PLAN, query_keys=_queries(pool), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_boundary_key_churn(self, num_shards):
        """Insert / delete / reinsert exactly the boundary keys, with cleanups."""
        bks = np.array(boundary_keys(), dtype=np.int64)
        n = len(bks)
        ops = [
            ("update", bks, np.arange(n, dtype=np.int32), np.zeros(n, bool)),
            ("update", bks[::2], np.zeros((n + 1) // 2, np.int32),
             np.ones((n + 1) // 2, bool)),                      # delete half
            ("cleanup",),
            ("update", bks, -np.arange(n, dtype=np.int32), np.zeros(n, bool)),
            ("update", bks[1::2], np.zeros(n // 2, np.int32), np.ones(n // 2, bool)),
            ("cleanup",),
        ]
        k1, k2 = query_ranges(bks)
        run_differential(
            _make_backends(num_shards), ops,
            batch_size=B, plan=PLAN, query_keys=_queries(bks), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_tombstone_churn_same_keys(self, num_shards):
        """Insert+delete the same tiny key set repeatedly: size must track the
        oracle through heavy stale-element accumulation and cleanup."""
        rng = np.random.default_rng(7)
        pool = np.array([0, 3, 5, sem.MAX_USER_KEY], dtype=np.int64)
        ops = gen_ops(rng, pool, n_steps=10, batch_size=B,
                      p_cleanup=0.2, p_delete=0.5, max_batches=2)
        k1, k2 = query_ranges(pool)
        run_differential(
            _make_backends(num_shards), ops,
            batch_size=B, plan=PLAN, query_keys=_queries(pool), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_bulk_build_matches_incremental(self, num_shards):
        rng = np.random.default_rng(5)
        keys = rng.choice(sem.MAX_USER_KEY, 37, replace=False).astype(np.int64)
        vals = (keys % 997).astype(np.int32) - 500
        handles = _make_backends(num_shards)
        q = _queries(np.sort(keys))
        ref_f, ref_v = None, None
        for name, d in handles.items():
            built = d.bulk_build(keys, vals)
            assert int(built.size()) == len(keys), name
            f, v = built.lookup(q)
            f, v = np.asarray(f), np.where(np.asarray(f), np.asarray(v), 0)
            if ref_f is None:
                ref_f, ref_v = f, v
            else:
                np.testing.assert_array_equal(f, ref_f, err_msg=name)
                np.testing.assert_array_equal(v, ref_v, err_msg=name)


class TestShardedQueryPlan:
    """QueryPlan auto-sizing under sharding: per-shard windows smaller than a
    single shard's hits must flip the ok flag, never silently truncate."""

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_range_overflow_on_one_hot_shard_is_flagged(self, num_shards):
        # All keys land in shard 0's range: its per-shard window sees every hit.
        n = 40
        keys = np.arange(n, dtype=np.int64)
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, keys.astype(np.int32))
        small = QueryPlan(max_candidates=CAPACITY, max_results=16)
        rkeys, rvals, counts, ok = d.range(
            np.array([0]), np.array([sem.MAX_USER_KEY]), small
        )
        assert not bool(np.asarray(ok)[0])          # flagged, not silent
        assert int(np.asarray(counts)[0]) == n      # counts stay exact
        big = QueryPlan(max_candidates=CAPACITY, max_results=64)
        rkeys, _, counts, ok = d.range(np.array([0]), np.array([sem.MAX_USER_KEY]), big)
        assert bool(np.asarray(ok)[0])
        assert np.asarray(rkeys)[0, :n].tolist() == keys.tolist()

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_count_candidate_overflow_is_flagged(self, num_shards):
        n = 40
        keys = np.arange(n, dtype=np.int64)
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, keys.astype(np.int32))
        counts, ok = d.count(
            np.array([0]), np.array([sem.MAX_USER_KEY]),
            QueryPlan(max_candidates=16),
        )
        assert not bool(np.asarray(ok)[0])
        counts, ok = d.count(np.array([0]), np.array([sem.MAX_USER_KEY]), PLAN)
        assert bool(np.asarray(ok)[0]) and int(np.asarray(counts)[0]) == n

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_auto_plan_stays_exact_for_small_sharded_dicts(self, num_shards):
        # No explicit plan: resolved() sees the (per-shard == global) capacity
        # <= 4096, so auto-sizing must stay exact and ok must hold.
        keys = np.arange(50, dtype=np.int64) * range_size(4)  # spread over shards
        keys = np.unique(np.clip(keys, 0, sem.MAX_USER_KEY))
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, np.ones(len(keys), np.int32))
        counts, ok = d.count(np.array([0]), np.array([sem.MAX_USER_KEY]))
        assert bool(np.asarray(ok)[0]) and int(np.asarray(counts)[0]) == len(keys)


class TestShardedFacadeMechanics:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_num_shards_and_repr(self, num_shards):
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=3, num_shards=num_shards
        )
        assert d.num_shards == num_shards
        assert d.backend == "lsm_sharded"
        assert Dictionary.create("lsm", batch_size=B, num_levels=3).num_shards == 1

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_pytree_roundtrip(self, num_shards):
        import jax.tree_util as jtu

        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=3, num_shards=num_shards
        ).insert(np.array([4, 5]), np.array([40, 50]))
        leaves, treedef = jtu.tree_flatten(d)
        d2 = jtu.tree_unflatten(treedef, leaves)
        f, v = d2.lookup(np.array([4, 5]))
        assert np.asarray(f).tolist() == [True, True]
        assert np.asarray(v).tolist() == [40, 50]

    def test_mesh_option_roundtrip_and_validation(self):
        from repro.launch.mesh import make_shard_mesh

        mesh = make_shard_mesh(1)
        d = Dictionary.create("lsm_sharded", batch_size=B, num_levels=3, mesh=mesh)
        assert d.num_shards == 1
        with pytest.raises(ValueError, match="no axis"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              mesh=mesh, axis="nope")
        with pytest.raises(ValueError, match="disagrees"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              mesh=mesh, num_shards=2)
        with pytest.raises(ValueError, match="num_shards"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              num_shards=len(jax.devices()) + 1)

    @_needs_devices(4)
    def test_overflow_latches_across_shards(self):
        d = Dictionary.create("lsm_sharded", batch_size=4, num_levels=1, num_shards=4)
        d = d.insert(np.array([1, 2, 3, 4]), np.zeros(4, np.int32))
        assert not bool(d.overflowed())
        d = d.insert(np.array([5, 6, 7, 8]), np.zeros(4, np.int32))
        assert bool(d.overflowed())  # every shard's counter ticked past max

    def test_bulk_build_capacity_check(self):
        d = Dictionary.create("lsm_sharded", batch_size=4, num_levels=1, num_shards=1)
        keys = np.arange(5, dtype=np.int64)
        with pytest.raises(ValueError, match="capacity"):
            d.bulk_build(keys, keys.astype(np.int32))


# ---------------------------------------------------------------------------
# Hypothesis-driven variants (same harness core, generated op sequences)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _POOL = np.array(boundary_keys() + [2, 40, 1000, 77777], dtype=np.int64)

    @st.composite
    def op_sequences(draw):
        n_steps = draw(st.integers(1, 6))
        ops = []
        for _ in range(n_steps):
            if draw(st.integers(0, 7)) == 0:
                ops.append(("cleanup",))
                continue
            n = draw(st.integers(1, 3 * B))
            idx = draw(st.lists(st.integers(0, len(_POOL) - 1),
                                min_size=n, max_size=n))
            vals = draw(st.lists(st.integers(-1000, 1000), min_size=n, max_size=n))
            dels = draw(st.lists(st.booleans(), min_size=n, max_size=n))
            ops.append((
                "update",
                _POOL[np.array(idx)],
                np.array(vals, np.int32),
                np.array(dels, bool),
            ))
        return ops


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestHypothesisParity:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_generated_sequences(self, num_shards):
        @settings(max_examples=15, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(ops=op_sequences())
        def run(ops):
            k1, k2 = query_ranges(_POOL)
            run_differential(
                _make_backends(num_shards), ops,
                batch_size=B, plan=PLAN, query_keys=_queries(_POOL),
                k1=k1, k2=k2, check_every=2,
            )

        run()
