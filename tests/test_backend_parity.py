"""Cross-backend differential parity: lsm / sorted_array / lsm_sharded.

Every backend with the full capability row must be *the same dictionary*
behind the facade: identical lookup / size / count / range answers (down to
range-row placebo padding) on randomized op sequences with ragged
(non-multiple-of-b) lengths, duplicate keys, tombstone churn, explicit and
implicit (overflow) write-buffer flushes, and boundary keys at 0 /
MAX_USER_KEY / shard boundaries — all checked against a Python-dict oracle
that models the write-buffer recency rule exactly (tests/harness.py), with
buffer-resident elements and tombstones visible to every query before any
flush.

The sharded backend runs at 1 / 2 / 4 shards on spoofed CPU devices
(conftest forces --xla_force_host_platform_device_count=4 before jax
initializes; CI additionally runs this file in a dedicated multi-device
job). Hypothesis variants of the same harness are marked `slow` and skip
when hypothesis is not installed.
"""

import jax
import numpy as np
import pytest

from repro.api import Dictionary, QueryPlan
from repro.core import semantics as sem

from harness import (
    boundary_keys,
    gen_ops,
    key_pool,
    maintain_budgets,
    query_ranges,
    range_size,
    run_differential,
)

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is a dev-only dep; the seeded tests still run
    HAVE_HYPOTHESIS = False

B = 8
NUM_LEVELS = 6  # capacity 8 * 63 = 504 for every run-based backend
CAPACITY = B * ((1 << NUM_LEVELS) - 1)
PLAN = QueryPlan(max_candidates=CAPACITY, max_results=64)


def _needs_devices(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n, reason=f"needs {n} (forced) host devices"
    )


SHARD_PARAMS = [
    pytest.param(1, id="shards1"),
    pytest.param(2, marks=_needs_devices(2), id="shards2"),
    pytest.param(4, marks=_needs_devices(4), id="shards4"),
]


def _make_backends(num_shards):
    return {
        "lsm": Dictionary.create("lsm", batch_size=B, num_levels=NUM_LEVELS),
        "sorted_array": Dictionary.create(
            "sorted_array", batch_size=B, capacity=CAPACITY
        ),
        f"lsm_sharded@{num_shards}": Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ),
    }


def _queries(pool):
    qs = np.concatenate([pool, np.clip(pool + 1, 0, sem.MAX_USER_KEY)])
    return np.unique(qs)


class TestDifferentialParity:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_randomized_sequences(self, seed, num_shards):
        rng = np.random.default_rng(seed)
        pool = key_pool(rng)
        ops = gen_ops(rng, pool, n_steps=8, batch_size=B)
        k1, k2 = query_ranges(pool)
        run_differential(
            _make_backends(num_shards), ops,
            plan=PLAN, query_keys=_queries(pool), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_boundary_key_churn(self, num_shards):
        """Insert / delete / reinsert exactly the boundary keys, with cleanups."""
        bks = np.array(boundary_keys(), dtype=np.int64)
        n = len(bks)
        ops = [
            ("update", bks, np.arange(n, dtype=np.int32), np.zeros(n, bool)),
            ("update", bks[::2], np.zeros((n + 1) // 2, np.int32),
             np.ones((n + 1) // 2, bool)),                      # delete half
            ("cleanup",),
            ("update", bks, -np.arange(n, dtype=np.int32), np.zeros(n, bool)),
            ("update", bks[1::2], np.zeros(n // 2, np.int32), np.ones(n // 2, bool)),
            ("cleanup",),
        ]
        k1, k2 = query_ranges(bks)
        run_differential(
            _make_backends(num_shards), ops,
            plan=PLAN, query_keys=_queries(bks), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_tombstone_churn_same_keys(self, num_shards):
        """Insert+delete the same tiny key set repeatedly: size must track the
        oracle through heavy stale-element accumulation and cleanup."""
        rng = np.random.default_rng(7)
        pool = np.array([0, 3, 5, sem.MAX_USER_KEY], dtype=np.int64)
        ops = gen_ops(rng, pool, n_steps=10, batch_size=B,
                      p_cleanup=0.2, p_delete=0.5, max_batches=2)
        k1, k2 = query_ranges(pool)
        run_differential(
            _make_backends(num_shards), ops,
            plan=PLAN, query_keys=_queries(pool), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_maintain_heavy_churn(self, num_shards):
        """Budgeted maintenance interleaved with every flavor of churn must be
        observationally invisible on every backend (sorted_array, which has no
        maintain, doubles as the never-compacted control)."""
        rng = np.random.default_rng(11)
        pool = key_pool(rng)
        ops = gen_ops(rng, pool, n_steps=10, batch_size=B,
                      p_cleanup=0.05, p_delete=0.45, p_maintain=0.4)
        assert any(op[0] == "maintain" for op in ops)
        k1, k2 = query_ranges(pool)
        run_differential(
            _make_backends(num_shards), ops,
            plan=PLAN, query_keys=_queries(pool), k1=k1, k2=k2,
        )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_bulk_build_matches_incremental(self, num_shards):
        rng = np.random.default_rng(5)
        keys = rng.choice(sem.MAX_USER_KEY, 37, replace=False).astype(np.int64)
        vals = (keys % 997).astype(np.int32) - 500
        handles = _make_backends(num_shards)
        q = _queries(np.sort(keys))
        ref_f, ref_v = None, None
        for name, d in handles.items():
            built = d.bulk_build(keys, vals)
            assert int(built.size()) == len(keys), name
            f, v = built.lookup(q)
            f, v = np.asarray(f), np.where(np.asarray(f), np.asarray(v), 0)
            if ref_f is None:
                ref_f, ref_v = f, v
            else:
                np.testing.assert_array_equal(f, ref_f, err_msg=name)
                np.testing.assert_array_equal(v, ref_v, err_msg=name)


class TestShardedQueryPlan:
    """QueryPlan auto-sizing under sharding: per-shard windows smaller than a
    single shard's hits must flip the ok flag, never silently truncate."""

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_range_overflow_on_one_hot_shard_is_flagged(self, num_shards):
        # All keys land in shard 0's range: its per-shard window sees every hit.
        n = 40
        keys = np.arange(n, dtype=np.int64)
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, keys.astype(np.int32))
        small = QueryPlan(max_candidates=CAPACITY, max_results=16)
        rkeys, rvals, counts, ok = d.range(
            np.array([0]), np.array([sem.MAX_USER_KEY]), small
        )
        assert not bool(np.asarray(ok)[0])          # flagged, not silent
        assert int(np.asarray(counts)[0]) == n      # counts stay exact
        big = QueryPlan(max_candidates=CAPACITY, max_results=64)
        rkeys, _, counts, ok = d.range(np.array([0]), np.array([sem.MAX_USER_KEY]), big)
        assert bool(np.asarray(ok)[0])
        assert np.asarray(rkeys)[0, :n].tolist() == keys.tolist()

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_count_candidate_overflow_is_flagged(self, num_shards):
        n = 40
        keys = np.arange(n, dtype=np.int64)
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, keys.astype(np.int32))
        counts, ok = d.count(
            np.array([0]), np.array([sem.MAX_USER_KEY]),
            QueryPlan(max_candidates=16),
        )
        assert not bool(np.asarray(ok)[0])
        counts, ok = d.count(np.array([0]), np.array([sem.MAX_USER_KEY]), PLAN)
        assert bool(np.asarray(ok)[0]) and int(np.asarray(counts)[0]) == n

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_auto_plan_stays_exact_for_small_sharded_dicts(self, num_shards):
        # No explicit plan: resolved() sees the (per-shard == global) capacity
        # <= 4096, so auto-sizing must stay exact and ok must hold.
        keys = np.arange(50, dtype=np.int64) * range_size(4)  # spread over shards
        keys = np.unique(np.clip(keys, 0, sem.MAX_USER_KEY))
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=NUM_LEVELS, num_shards=num_shards
        ).insert(keys, np.ones(len(keys), np.int32))
        counts, ok = d.count(np.array([0]), np.array([sem.MAX_USER_KEY]))
        assert bool(np.asarray(ok)[0]) and int(np.asarray(counts)[0]) == len(keys)


class TestShardedFacadeMechanics:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_num_shards_and_repr(self, num_shards):
        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=3, num_shards=num_shards
        )
        assert d.num_shards == num_shards
        assert d.backend == "lsm_sharded"
        assert Dictionary.create("lsm", batch_size=B, num_levels=3).num_shards == 1

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_pytree_roundtrip(self, num_shards):
        import jax.tree_util as jtu

        d = Dictionary.create(
            "lsm_sharded", batch_size=B, num_levels=3, num_shards=num_shards
        ).insert(np.array([4, 5]), np.array([40, 50]))
        leaves, treedef = jtu.tree_flatten(d)
        d2 = jtu.tree_unflatten(treedef, leaves)
        f, v = d2.lookup(np.array([4, 5]))
        assert np.asarray(f).tolist() == [True, True]
        assert np.asarray(v).tolist() == [40, 50]

    def test_mesh_option_roundtrip_and_validation(self):
        from repro.launch.mesh import make_shard_mesh

        mesh = make_shard_mesh(1)
        d = Dictionary.create("lsm_sharded", batch_size=B, num_levels=3, mesh=mesh)
        assert d.num_shards == 1
        with pytest.raises(ValueError, match="no axis"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              mesh=mesh, axis="nope")
        with pytest.raises(ValueError, match="disagrees"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              mesh=mesh, num_shards=2)
        with pytest.raises(ValueError, match="num_shards"):
            Dictionary.create("lsm_sharded", batch_size=B, num_levels=3,
                              num_shards=len(jax.devices()) + 1)

    @_needs_devices(4)
    def test_overflow_latches_across_shards(self):
        # All keys land in shard 0: its buffer (4 slots) + its one batch slot
        # absorb 8 elements; the 9th forces a flush past the slot budget.
        d = Dictionary.create("lsm_sharded", batch_size=4, num_levels=1, num_shards=4)
        d = d.insert(np.array([1, 2, 3, 4]), np.zeros(4, np.int32))
        assert not bool(d.overflowed())
        d = d.insert(np.array([5, 6, 7, 8]), np.zeros(4, np.int32))
        assert not bool(d.overflowed())  # write-buffer grace on shard 0
        d = d.insert(np.array([9]), np.zeros(1, np.int32))
        assert bool(d.overflowed())

    def test_bulk_build_capacity_check(self):
        d = Dictionary.create("lsm_sharded", batch_size=4, num_levels=1, num_shards=1)
        keys = np.arange(5, dtype=np.int64)
        with pytest.raises(ValueError, match="capacity"):
            d.bulk_build(keys, keys.astype(np.int32))


class TestWriteBuffer:
    """The staging buffer ("level −1"): pre-flush visibility, slot
    accounting, explicit/threshold flushes, masked lanes."""

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_buffer_tombstones_visible_before_flush(self, num_shards):
        """A tombstone that is still buffer-resident must hide an older,
        already-flushed insert from lookup/count/range/size."""
        bks = boundary_keys()[:6]
        keys = np.array(bks, dtype=np.int64)
        vals = np.arange(len(keys), dtype=np.int32) + 1
        for name, d in _make_backends(num_shards).items():
            d = d.insert(keys, vals).flush()          # all keys in the levels
            d = d.delete(keys[::2])                   # tombstones staged only
            f, _ = d.lookup(keys)
            exp = np.ones(len(keys), bool)
            exp[::2] = False
            np.testing.assert_array_equal(np.asarray(f), exp, err_msg=name)
            assert int(d.size()) == len(keys) - len(keys[::2]), name
            c, ok = d.count(
                np.array([0]), np.array([sem.MAX_USER_KEY]), PLAN
            )
            assert bool(np.asarray(ok)[0]) and int(np.asarray(c)[0]) == len(keys[1::2]), name
            rk, _, rc, rok = d.range(
                np.array([0]), np.array([sem.MAX_USER_KEY]), PLAN
            )
            assert bool(np.asarray(rok)[0]), name
            got = np.asarray(rk)[0, : int(np.asarray(rc)[0])].tolist()
            assert got == sorted(int(k) for k in keys[1::2]), name

    def test_sub_batch_slot_accounting(self):
        """N size-1 inserts consume floor((N-1)/b) batch slots — not N — and
        r*b + pending always equals the number of staged elements."""
        d = Dictionary.create("lsm", batch_size=B, num_levels=NUM_LEVELS)
        for i in range(1, 3 * B + 2):
            d = d.insert(np.array([i]), np.array([i]))
            assert int(d.state.r) == (i - 1) // B, i
            assert int(d.state.r) * B + int(d.pending()) == i, i
        f, _ = d.lookup(np.arange(1, 3 * B + 2))
        assert bool(np.asarray(f).all())

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_explicit_flush_is_query_transparent(self, num_shards):
        keys = np.array(boundary_keys()[:5], dtype=np.int64)
        q = _queries(keys)
        k1, k2 = query_ranges(keys)
        for name, d in _make_backends(num_shards).items():
            d = d.insert(keys, (keys % 97).astype(np.int32))
            before = [np.asarray(x) for x in (*d.lookup(q), d.size())]
            flushed = d.flush()
            assert int(flushed.pending()) == 0, name
            after = [np.asarray(x) for x in (*flushed.lookup(q), flushed.size())]
            for a, b_ in zip(before, after):
                np.testing.assert_array_equal(a, b_, err_msg=name)
            # idempotent: flushing an empty buffer is a no-op (capture r
            # first — flush() donates the receiving handle's buffers)
            r_before = int(flushed.state.r) if name == "lsm" else None
            again = flushed.flush()
            assert int(again.pending()) == 0, name
            if name == "lsm":
                assert int(again.state.r) == r_before, name

    def test_flush_threshold_policy(self):
        # threshold=1 restores the old pad-every-call slot profile
        d1 = Dictionary.create(
            "lsm", batch_size=B, num_levels=NUM_LEVELS, flush_threshold=1
        )
        for i in range(3):
            d1 = d1.insert(np.array([i]), np.array([i]))
            assert int(d1.pending()) == 0
            assert int(d1.state.r) == i + 1
        # threshold=B flushes only once the buffer is exactly full
        dB = Dictionary.create(
            "lsm", batch_size=B, num_levels=NUM_LEVELS, flush_threshold=B
        )
        for i in range(B - 1):
            dB = dB.insert(np.array([i]), np.array([i]))
        assert int(dB.pending()) == B - 1 and int(dB.state.r) == 0
        dB = dB.insert(np.array([B - 1]), np.array([B - 1]))
        assert int(dB.pending()) == 0 and int(dB.state.r) == 1
        with pytest.raises(ValueError, match="flush_threshold"):
            Dictionary.create("lsm", batch_size=B, num_levels=3, flush_threshold=B + 1)

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_masked_lanes_do_not_occupy_buffer_slots(self, num_shards):
        rs = range_size(num_shards)
        keys = np.array([1, 2, rs, rs + 1, 2 * rs, 3], dtype=np.int64)
        keys = np.clip(keys, 0, sem.MAX_USER_KEY)
        valid = np.array([True, False, True, False, True, False])
        for name, d in _make_backends(num_shards).items():
            d = d.update(keys, np.arange(6, dtype=np.int32), valid=valid)
            assert int(d.pending()) in (0, 3), name  # 0 for sorted_array
            if name != "sorted_array":
                assert int(d.pending()) == 3, name
            assert int(d.size()) == len(np.unique(keys[valid])), name
            f, _ = d.lookup(keys)
            np.testing.assert_array_equal(
                np.asarray(f),
                np.array([k in set(keys[valid].tolist()) for k in keys.tolist()]),
                err_msg=name,
            )

    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_mixed_update_with_masked_lanes_in_buffer(self, num_shards):
        """is_delete + valid together: masked tombstones must not delete,
        masked inserts must not appear, and nothing masked occupies the
        buffer — the facade analogue of lsm_update_mixed against level −1."""
        for name, d in _make_backends(num_shards).items():
            d = d.insert(np.array([10, 20, 30]), np.array([1, 2, 3])).flush()
            d = d.update(
                np.array([10, 20, 40, 50]),
                np.array([0, 0, 4, 5]),
                is_delete=np.array([True, True, False, False]),
                valid=np.array([True, False, True, False]),
            )
            f, v = d.lookup(np.array([10, 20, 30, 40, 50]))
            np.testing.assert_array_equal(
                np.asarray(f), [False, True, True, True, False], err_msg=name
            )
            np.testing.assert_array_equal(
                np.where(np.asarray(f), np.asarray(v), 0), [0, 2, 3, 4, 0],
                err_msg=name,
            )
            if name != "sorted_array":
                assert int(d.pending()) == 2, name  # the tombstone + one insert
            assert int(d.size()) == 3, name


# ---------------------------------------------------------------------------
# Hypothesis-driven variants (same harness core, generated op sequences)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _POOL = np.array(boundary_keys() + [2, 40, 1000, 77777], dtype=np.int64)

    @st.composite
    def op_sequences(draw):
        n_steps = draw(st.integers(1, 6))
        ops = []
        for _ in range(n_steps):
            roll = draw(st.integers(0, 9))
            if roll == 0:
                ops.append(("cleanup",))
                continue
            if roll == 1:
                budgets = maintain_budgets(B)
                ops.append(("maintain",
                            budgets[draw(st.integers(0, len(budgets) - 1))]))
                continue
            n = draw(st.integers(1, 3 * B))
            idx = draw(st.lists(st.integers(0, len(_POOL) - 1),
                                min_size=n, max_size=n))
            vals = draw(st.lists(st.integers(-1000, 1000), min_size=n, max_size=n))
            dels = draw(st.lists(st.booleans(), min_size=n, max_size=n))
            ops.append((
                "update",
                _POOL[np.array(idx)],
                np.array(vals, np.int32),
                np.array(dels, bool),
            ))
        return ops


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestHypothesisParity:
    @pytest.mark.parametrize("num_shards", SHARD_PARAMS)
    def test_generated_sequences(self, num_shards):
        @settings(max_examples=15, deadline=None,
                  suppress_health_check=[HealthCheck.too_slow])
        @given(ops=op_sequences())
        def run(ops):
            k1, k2 = query_ranges(_POOL)
            run_differential(
                _make_backends(num_shards), ops,
                plan=PLAN, query_keys=_queries(_POOL),
                k1=k1, k2=k2, check_every=2,
            )

        run()
