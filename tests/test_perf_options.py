"""PerfOptions must be pure layout/scheduling changes: identical math.

Every §Perf optimization (sharded loss, ZeRO-3 regather, remat policy,
scan unroll) is checked for numerical equivalence against the baseline on
CPU — sharding hints degrade to no-ops off-mesh, remat/unroll never change
values, and the sharded CE is an algebraic rewrite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import model_zoo as zoo
from repro.train.options import PerfOptions
from repro.train.steps import softmax_xent

ARCHS = ("qwen2-7b", "olmoe-1b-7b", "mamba2-780m")


def test_sharded_xent_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 16, 97)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 97, (4, 16)), jnp.int32)
    a = softmax_xent(logits, labels, sharded=False)
    b = softmax_xent(logits, labels, sharded=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("arch", ARCHS)
def test_options_do_not_change_forward(arch):
    cfg = get_smoke_config(arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32)}

    ref, _ = zoo.apply_train(cfg, params, batch, options=PerfOptions())
    for opts in (
        PerfOptions(zero3_gather=True, sharded_loss=True),
        PerfOptions(remat_policy="dots"),
        PerfOptions(remat_policy="none"),
        PerfOptions(scan_unroll=-1),
        PerfOptions(scan_unroll=2, attn_seq_shard=True),
    ):
        out, _ = zoo.apply_train(cfg, params, batch, options=opts)
        np.testing.assert_allclose(
            np.asarray(ref, np.float32), np.asarray(out, np.float32),
            rtol=5e-2, atol=5e-2,
        )


def test_options_do_not_change_gradients():
    cfg = get_smoke_config("stablelm-1.6b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }

    def loss(p, opts):
        logits, _ = zoo.apply_train(cfg, p, batch, options=opts)
        return softmax_xent(logits, batch["labels"], sharded=opts.sharded_loss)

    g_ref = jax.grad(lambda p: loss(p, PerfOptions()))(params)
    g_opt = jax.grad(lambda p: loss(p, PerfOptions(sharded_loss=True,
                                                   zero3_gather=True,
                                                   remat_policy="dots")))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_opt)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=5e-2, atol=1e-3
        )
