"""Interpret-mode parity for the fused read-path kernels (ISSUE 7 satellite).

Two new Pallas kernels back the fused read path:

  * `lsm_lookup.fused_lookup_runs` — one streaming kernel per query block
    that walks ALL runs (concatenated newest-first) behind double-buffered
    DMA, replacing the per-run `lower_bound` loop;
  * `merge_path.merge_cascade_path` — one K-way Merge Path launch that
    streams K runs through VMEM, replacing the pairwise merge chain in a
    cascade step.

Both are checked bitwise (integer data) against the pure-jnp oracles in
`kernels/ref.py` across run counts 0..max, empty (all-placebo) levels,
buffer-only configurations, and duplicate/tombstone-heavy distributions —
and the `ops` dispatch layer is checked end-to-end: the fused XLA/Pallas
answers must agree with the per-run reference resolution on real LSM states.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import semantics as sem
from repro.kernels import lsm_lookup, merge_path, ops, ref

RNG = np.random.default_rng(1234)


def _sorted_run(n, key_hi, tombstone_frac=0.2, placebo_frac=0.0):
    """One sorted run of key-variables; optionally placebo-diluted."""
    keys = RNG.integers(0, key_hi, n).astype(np.int32)
    status = (RNG.random(n) > tombstone_frac).astype(np.int32)
    kv = ((keys << 1) | status).astype(np.int32)
    if placebo_frac:
        kv = np.where(RNG.random(n) < placebo_frac, sem.PLACEBO_KV, kv)
    kv = np.sort(kv)
    val = RNG.integers(1, 1 << 20, n).astype(np.int32)
    return jnp.array(kv), jnp.array(val)


def _placebo_run(n):
    return (
        jnp.full((n,), sem.PLACEBO_KV, jnp.int32),
        jnp.full((n,), sem.EMPTY_VALUE, jnp.int32),
    )


# ---------------------------------------------------------------------------
# fused_lookup_runs (kernel level, interpret mode)
# ---------------------------------------------------------------------------


class TestFusedLookupKernel:
    def _check(self, runs, queries, chunk=256, query_block=256, depth=2):
        flat_kv = jnp.concatenate([kv for kv, _ in runs])
        flat_val = jnp.concatenate([v for _, v in runs])
        pad = -flat_kv.shape[0] % chunk
        if pad:
            pkv, pval = _placebo_run(pad)
            flat_kv = jnp.concatenate([flat_kv, pkv])
            flat_val = jnp.concatenate([flat_val, pval])
        q = jnp.asarray(queries, jnp.int32)
        qpad = -q.shape[0] % query_block
        if qpad:
            q = jnp.concatenate([q, jnp.full((qpad,), sem.PLACEBO_KEY, jnp.int32)])
        got_kv, got_val = lsm_lookup.fused_lookup_runs(
            flat_kv, flat_val, q,
            chunk=chunk, query_block=query_block, depth=depth, interpret=True,
        )
        exp_kv, exp_val = ref.fused_lookup_ref(flat_kv, flat_val, q)
        np.testing.assert_array_equal(np.asarray(got_kv), np.asarray(exp_kv))
        np.testing.assert_array_equal(np.asarray(got_val), np.asarray(exp_val))

    @pytest.mark.parametrize("num_runs", [1, 2, 3, 5])
    @pytest.mark.parametrize("key_hi", [8, 500, 1 << 20])
    def test_multi_run_parity(self, num_runs, key_hi):
        runs = [_sorted_run(256 << i, key_hi) for i in range(num_runs)]
        queries = RNG.integers(0, key_hi + 2, 300).astype(np.int32)
        self._check(runs, queries)

    def test_empty_levels_are_invisible(self):
        # Placebo-only runs between real runs must never win a query.
        real1 = _sorted_run(256, 100, tombstone_frac=0.0)
        real2 = _sorted_run(512, 100, tombstone_frac=0.5)
        runs = [real1, _placebo_run(256), real2, _placebo_run(512)]
        self._check(runs, np.arange(0, 110).astype(np.int32))

    def test_buffer_only_single_chunk(self):
        runs = [_sorted_run(256, 40, tombstone_frac=0.3)]
        self._check(runs, np.arange(0, 48).astype(np.int32))

    def test_all_placebo_structure_finds_nothing(self):
        runs = [_placebo_run(512)]
        q = jnp.arange(256, dtype=jnp.int32)
        flat_kv, flat_val = runs[0]
        got_kv, got_val = lsm_lookup.fused_lookup_runs(
            flat_kv, flat_val, q, chunk=256, query_block=256, interpret=True
        )
        assert (np.asarray(got_kv) == sem.PLACEBO_KV).all()
        assert (np.asarray(got_val) == sem.EMPTY_VALUE).all()

    def test_dup_tombstone_heavy_newest_wins(self):
        # Tiny key space: every key occurs in several runs with mixed status.
        runs = [_sorted_run(256, 6, tombstone_frac=0.5) for _ in range(4)]
        self._check(runs, np.arange(0, 8).astype(np.int32))

    @pytest.mark.parametrize("depth", [1, 2, 3])
    def test_pipeline_depth_invariance(self, depth):
        # The DMA double-buffer depth must never change the answer.
        runs = [_sorted_run(256, 200), _sorted_run(512, 200)]
        self._check(runs, np.arange(0, 200, 3).astype(np.int32), depth=depth)

    def test_newer_run_shadows_older(self):
        # Same key everywhere: the FIRST (newest) run's element must win.
        k = 7
        runs = []
        for i in range(3):
            kv = jnp.full((256,), (k << 1) | 1, jnp.int32)
            val = jnp.full((256,), 100 + i, jnp.int32)
            runs.append((kv, val))
        flat_kv = jnp.concatenate([kv for kv, _ in runs])
        flat_val = jnp.concatenate([v for _, v in runs])
        q = jnp.full((256,), k, jnp.int32)
        got_kv, got_val = lsm_lookup.fused_lookup_runs(
            flat_kv, flat_val, q, chunk=256, query_block=256, interpret=True
        )
        assert (np.asarray(got_val) == 100).all()
        # ... and a newest tombstone must shadow older inserts.
        runs[0] = (jnp.full((256,), k << 1, jnp.int32), jnp.zeros((256,), jnp.int32))
        flat_kv = jnp.concatenate([kv for kv, _ in runs])
        flat_val = jnp.concatenate([v for _, v in runs])
        got_kv, _ = lsm_lookup.fused_lookup_runs(
            flat_kv, flat_val, q, chunk=256, query_block=256, interpret=True
        )
        assert (np.asarray(got_kv) == k << 1).all()  # tombstone kv wins


# ---------------------------------------------------------------------------
# merge_cascade_path (kernel level, interpret mode)
# ---------------------------------------------------------------------------


class TestCascadeMergeKernel:
    def _check(self, runs, **kw):
        runs_kv = [kv for kv, _ in runs]
        runs_val = [v for _, v in runs]
        exp_kv, exp_val = ref.merge_cascade_ref(runs_kv, runs_val)
        got_kv, got_val = merge_path.merge_cascade_path(
            runs_kv, runs_val, interpret=True, **kw
        )
        np.testing.assert_array_equal(np.asarray(got_kv), np.asarray(exp_kv))
        np.testing.assert_array_equal(np.asarray(got_val), np.asarray(exp_val))

    @pytest.mark.parametrize("sizes", [
        (256,), (256, 256), (256, 512), (256, 256, 512),
        (256, 512, 1024, 2048), (512, 256, 256, 512, 1024),
    ])
    @pytest.mark.parametrize("key_hi", [8, 1000, 1 << 20])
    def test_k_way_parity(self, sizes, key_hi):
        self._check([_sorted_run(n, key_hi) for n in sizes])

    def test_placebo_runs_sort_last(self):
        runs = [_sorted_run(256, 50), _placebo_run(512), _sorted_run(256, 50)]
        self._check(runs)

    def test_ties_keep_earlier_run_first(self):
        # All-equal key variables across K runs: output must preserve run
        # order (earlier run = newer = first), the cascade recency invariant.
        kv = (5 << 1) | 1
        runs = [
            (jnp.full((256,), kv, jnp.int32),
             jnp.full((256,), i, jnp.int32))
            for i in range(3)
        ]
        got_kv, got_val = merge_path.merge_cascade_path(
            [kv for kv, _ in runs], [v for _, v in runs], interpret=True
        )
        got_val = np.asarray(got_val)
        for i in range(3):
            assert (got_val[i * 256:(i + 1) * 256] == i).all()

    def test_dup_tombstone_heavy(self):
        runs = [_sorted_run(256, 5, tombstone_frac=0.6, placebo_frac=0.2)
                for _ in range(4)]
        self._check(runs)

    def test_cascade_partition_bounds_are_exact(self):
        runs = [np.asarray(kv) >> 1 for kv, _ in
                [_sorted_run(256, 300), _sorted_run(512, 300), _sorted_run(256, 300)]]
        total = sum(len(r) for r in runs)
        diags = jnp.arange(0, total + 1, 64, dtype=jnp.int32)
        bounds = np.asarray(merge_path.cascade_partition(
            [jnp.array(r) for r in runs], diags
        ))
        # Each diagonal's bounds must sum to the diagonal and be monotone.
        np.testing.assert_array_equal(bounds.sum(axis=0), np.asarray(diags))
        assert (np.diff(bounds, axis=1) >= 0).all()
        # Merge-path dominance: everything taken is <= everything not taken.
        for t, d in enumerate(np.asarray(diags)):
            taken = np.concatenate([r[: bounds[s, t]] for s, r in enumerate(runs)] or [np.array([])])
            rest = np.concatenate([r[bounds[s, t]:] for s, r in enumerate(runs)] or [np.array([])])
            if len(taken) and len(rest):
                assert taken.max() <= rest.min(), f"diag {d}"


# ---------------------------------------------------------------------------
# ops dispatch layer (end-to-end on LSM states, XLA vs Pallas-interpret)
# ---------------------------------------------------------------------------


class TestOpsDispatch:
    def test_merge_cascade_falls_back_on_ragged_sizes(self):
        # Non-multiple-of-BLOCK runs must still merge correctly (XLA fold).
        runs = [_sorted_run(100, 50), _sorted_run(33, 50), _sorted_run(256, 50)]
        exp = ref.merge_cascade_ref([kv for kv, _ in runs], [v for _, v in runs])
        got = ops.merge_cascade(runs)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(exp[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(exp[1]))

    def test_merge_cascade_single_run_passthrough(self):
        run = _sorted_run(256, 50)
        got = ops.merge_cascade([run])
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(run[0]))

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_lookup_runs_end_to_end(self, backend):
        """Real LSM states at 0..max resident runs: the dispatched lookup
        (fused kernel on pallas, per-run loop on xla) must match a dict
        oracle replay exactly — including buffer-only and empty states."""
        from repro.core import LSMConfig, all_runs, lsm_init, lsm_update
        from repro.core.queries import lookup_runs

        old = ops.get_backend()
        ops.set_backend(backend)
        try:
            cfg = LSMConfig(batch_size=256, num_levels=3)
            state = lsm_init(cfg)
            oracle = {}
            queries = np.arange(0, 600, dtype=np.int32)

            def check(state, tag):
                found, vals = lookup_runs(all_runs(cfg, state), jnp.array(queries))
                found, vals = np.asarray(found), np.asarray(vals)
                exp_f = np.array([int(k) in oracle for k in queries])
                np.testing.assert_array_equal(found, exp_f, err_msg=tag)
                exp_v = np.array([oracle.get(int(k), 0) for k in queries])
                np.testing.assert_array_equal(
                    np.where(found, vals, 0), np.where(exp_f, exp_v, 0), err_msg=tag
                )

            check(state, "empty")
            rng = np.random.default_rng(9)
            for step in range(5):  # fills levels through several cascades
                # Unique keys per batch: the core's in-batch rule (paper §3.3,
                # tombstone-first after the sort) differs from arrival order,
                # so duplicate keys inside ONE batch have no dict-oracle
                # meaning. Cross-batch duplicates still churn heavily.
                keys = rng.choice(500, 256, replace=False).astype(np.int32)
                dels = rng.random(256) < 0.3
                kv = jnp.array(((keys << 1) | (~dels).astype(np.int32)).astype(np.int32))
                vals = jnp.array(rng.integers(1, 1000, 256).astype(np.int32))
                state = lsm_update(cfg, state, kv, vals)
                for k, v, d in zip(keys.tolist(), np.asarray(vals).tolist(), dels.tolist()):
                    if d:
                        oracle.pop(k, None)
                    else:
                        oracle[k] = v
                check(state, f"after update {step} (r={int(state.r)})")
        finally:
            ops.set_backend(old)

    def test_fused_and_loop_paths_agree_bitwise(self):
        """The pallas fused path and the xla per-run loop must return the
        same (found, values) arrays on identical runs."""
        from repro.core.queries import lookup_runs

        runs = [_sorted_run(256, 300, tombstone_frac=0.4) for _ in range(3)]
        queries = jnp.array(RNG.integers(0, 310, 500).astype(np.int32))
        old = ops.get_backend()
        try:
            ops.set_backend("xla")
            f_x, v_x = lookup_runs(runs, queries)
            ops.set_backend("pallas")
            assert ops.lookup_runs_fused(runs, queries) is not None
            f_p, v_p = lookup_runs(runs, queries)
        finally:
            ops.set_backend(old)
        np.testing.assert_array_equal(np.asarray(f_x), np.asarray(f_p))
        np.testing.assert_array_equal(np.asarray(v_x), np.asarray(v_p))
