"""Cross-backend differential test harness for the `Dictionary` facade.

One randomized op sequence (insert / delete / mixed update / cleanup /
explicit flush / budgeted maintain, with ragged non-multiple-of-b lengths,
duplicate keys, tombstone churn, and boundary keys at 0 / MAX_USER_KEY /
shard boundaries) is replayed against:

  * a Python-dict oracle that models the facade's documented duplicate
    semantics *exactly* — the write-buffer recency rule: lanes apply in
    strict arrival order, the later lane/call wins, and (unlike the paper's
    in-batch rule) a tombstone coalesced into the same eventual flush batch
    as a later insert of its key still loses to it. Chunk boundaries,
    buffer flushes, and cleanups are all semantically invisible; and
  * every backend under test — results must match the oracle AND each other
    bit-for-bit, including range-row placebo padding. Backends with a write
    buffer answer queries over staged elements (tombstones included) before
    any flush; the sorted array applies immediately — the oracle pins both
    to the same answers.

The generator is plain numpy driven by a seeded Generator so the same
sequences run with or without hypothesis installed;
tests/test_backend_parity.py layers a hypothesis strategy on top of the same
replay/check core when hypothesis is available.
"""

from __future__ import annotations

import numpy as np

from repro.api import QueryPlan
from repro.core import semantics as sem

# Shard counts the parity suite exercises; boundary keys are derived for all
# of them so every backend sees the same sequences.
SHARD_COUNTS = (1, 2, 4)


def range_size(num_shards: int) -> int:
    """Mirror of DistLSMConfig.range_size (keys per shard, last shard ragged)."""
    return (sem.PLACEBO_KEY + num_shards - 1) // num_shards


def boundary_keys(shard_counts=SHARD_COUNTS):
    """Domain edges + straddles of every shard boundary: s*rs - 1 / s*rs."""
    ks = {0, 1, sem.MAX_USER_KEY - 1, sem.MAX_USER_KEY}
    for num_shards in shard_counts:
        rs = range_size(num_shards)
        for s in range(1, num_shards):
            for k in (s * rs - 1, s * rs):
                if 0 <= k <= sem.MAX_USER_KEY:
                    ks.add(k)
    return sorted(ks)


def key_pool(rng: np.random.Generator, extra: int = 24, shard_counts=SHARD_COUNTS):
    """Boundary keys + a small dense cluster + scattered full-domain keys.

    Small pool + sampling WITH replacement in gen_ops = heavy duplicate-key
    and tombstone churn, which is what the paper's recency rules are about.
    """
    pool = set(boundary_keys(shard_counts))
    pool |= set(int(k) for k in rng.integers(0, 2000, extra // 2))
    pool |= set(int(k) for k in rng.integers(0, sem.MAX_USER_KEY + 1, extra - extra // 2))
    return np.array(sorted(pool), dtype=np.int64)


def maintain_budgets(batch_size: int):
    """Budget menu for ('maintain', budget) ops: prefix sizes that select
    level 0 / levels 0-1 / levels 0-2, plus None (degrades to full cleanup).
    All are valid for every backend — maintenance is a no-op where
    unsupported (run_differential skips those handles)."""
    return (batch_size, 3 * batch_size, 7 * batch_size, None)


def gen_ops(rng: np.random.Generator, pool, *, n_steps=8, batch_size=8,
            p_cleanup=0.12, p_delete=0.35, p_flush=0.1, p_maintain=0.12,
            max_batches=3):
    """Op sequence: ('update', keys, vals, dels) | ('cleanup',) | ('flush',)
    | ('maintain', budget).

    Update lengths span 1..max_batches*b + 1 and are deliberately not
    multiples of batch_size (exercises the write-buffer staging and the
    facade's compact/split), keys are drawn with replacement (duplicates),
    and values include negatives (exercises the sharded psum combine).
    Maintain ops draw a random budget from `maintain_budgets` — like cleanup
    and flush they must be observationally invisible, which is exactly what
    the oracle comparison enforces.
    """
    budgets = maintain_budgets(batch_size)
    ops = []
    for _ in range(n_steps):
        roll = rng.random()
        if roll < p_cleanup:
            ops.append(("cleanup",))
            continue
        if roll < p_cleanup + p_flush:
            ops.append(("flush",))
            continue
        if roll < p_cleanup + p_flush + p_maintain:
            ops.append(("maintain", budgets[int(rng.integers(len(budgets)))]))
            continue
        n = int(rng.integers(1, max_batches * batch_size + 2))
        keys = rng.choice(pool, n)
        vals = rng.integers(-1000, 1000, n).astype(np.int32)
        dels = rng.random(n) < p_delete
        ops.append(("update", keys, vals, dels))
    return ops


def oracle_apply(oracle: dict, op) -> None:
    """Replay one op on the dict oracle: strict arrival-order semantics.

    The write-buffer recency rule makes chunk boundaries invisible — every
    lane applies in sequence and the later write wins, so an insert arriving
    after a tombstone of the same key resurrects it even if both coalesce
    into one flush batch (unlike the paper's in-batch tombstone-first rule).
    Cleanup and flush are semantically invisible.
    """
    if op[0] in ("cleanup", "flush", "maintain"):
        return
    _, keys, vals, dels = op
    for k, v, d in zip(keys, vals, dels):
        if bool(d):
            oracle.pop(int(k), None)
        else:
            oracle[int(k)] = int(v)


def query_ranges(pool):
    """(k1, k2) pairs: full domain, boundary straddles, narrow, empty, inverted."""
    pool = np.asarray(pool, dtype=np.int64)
    mid = int(pool[len(pool) // 2])
    k1 = [0, 0, mid, int(pool[0]), sem.MAX_USER_KEY, 1000]
    k2 = [sem.MAX_USER_KEY, mid, sem.MAX_USER_KEY, int(pool[0]), sem.MAX_USER_KEY, 0]
    for num_shards in SHARD_COUNTS:
        rs = range_size(num_shards)
        for s in range(1, num_shards):
            k1.append(max(s * rs - 1, 0))
            k2.append(min(s * rs, sem.MAX_USER_KEY))
    return np.array(k1, dtype=np.int64), np.array(k2, dtype=np.int64)


def check_vs_oracle(name: str, d, oracle: dict, query_keys, k1, k2, plan: QueryPlan):
    """Assert one backend's lookup/size/count/range answers equal the oracle."""
    q = np.asarray(query_keys, dtype=np.int64)
    found, vals = d.lookup(q)
    found, vals = np.asarray(found), np.asarray(vals)
    exp_found = np.array([int(k) in oracle for k in q])
    np.testing.assert_array_equal(found, exp_found, err_msg=f"{name}: lookup found")
    exp_vals = np.array([oracle.get(int(k), 0) for k in q])
    np.testing.assert_array_equal(
        np.where(found, vals, 0), np.where(exp_found, exp_vals, 0),
        err_msg=f"{name}: lookup values",
    )
    assert int(d.size()) == len(oracle), (
        f"{name}: size() = {int(d.size())}, oracle has {len(oracle)} "
        "(write-buffer residents must be counted)"
    )

    counts, ok = d.count(k1, k2, plan)
    counts, ok = np.asarray(counts), np.asarray(ok)
    assert bool(ok.all()), f"{name}: count plan truncated (enlarge the test plan)"
    exp_counts = np.array(
        [sum(1 for k in oracle if a <= k <= b) for a, b in zip(k1.tolist(), k2.tolist())]
    )
    np.testing.assert_array_equal(counts, exp_counts, err_msg=f"{name}: counts")

    rkeys, rvals, rcounts, rok = d.range(k1, k2, plan)
    rkeys, rvals, rcounts = np.asarray(rkeys), np.asarray(rvals), np.asarray(rcounts)
    assert bool(np.asarray(rok).all()), f"{name}: range plan truncated"
    np.testing.assert_array_equal(rcounts, exp_counts, err_msg=f"{name}: range counts")
    for i, (a, b) in enumerate(zip(k1.tolist(), k2.tolist())):
        exp_keys = sorted(k for k in oracle if a <= k <= b)
        got_keys = rkeys[i, : rcounts[i]].tolist()
        assert got_keys == exp_keys, f"{name}: range[{i}] keys {got_keys} != {exp_keys}"
        assert rvals[i, : rcounts[i]].tolist() == [oracle[k] for k in exp_keys], (
            f"{name}: range[{i}] values"
        )
        # padding contract: placebo keys / empty values past counts[i]
        assert (rkeys[i, rcounts[i]:] == sem.PLACEBO_KEY).all(), f"{name}: key padding"
        assert (rvals[i, rcounts[i]:] == sem.EMPTY_VALUE).all(), f"{name}: value padding"
    return rkeys, rvals, rcounts


def run_differential(dicts: dict, ops, *, plan: QueryPlan,
                     query_keys, k1, k2, check_every: int = 1):
    """Replay `ops` on every handle in `dicts` ({name: Dictionary}).

    After each op (or every `check_every` ops, and always after the last),
    every backend is checked against the oracle and the backends' raw range
    outputs are checked against each other (identical arrays incl. padding).
    Checks between an update and its (explicit or overflow) flush pin the
    buffer-resident visibility contract. Returns the final handles.
    """
    oracle: dict = {}
    for step, op in enumerate(ops):
        if op[0] == "cleanup":
            dicts = {name: d.cleanup() for name, d in dicts.items()}
        elif op[0] == "flush":
            dicts = {name: d.flush() for name, d in dicts.items()}
        elif op[0] == "maintain":
            # No-op for backends without maintenance support — the point of
            # the check is that maintaining backends stay bit-identical to
            # the ones that never compact.
            dicts = {
                name: d.maintain(op[1]) if d.capabilities.supports_maintenance else d
                for name, d in dicts.items()
            }
        else:
            _, keys, vals, dels = op
            dicts = {
                name: d.update(keys, vals, is_delete=dels)
                for name, d in dicts.items()
            }
        oracle_apply(oracle, op)

        if step % check_every and step != len(ops) - 1:
            continue
        raw = {
            name: check_vs_oracle(name, d, oracle, query_keys, k1, k2, plan)
            for name, d in dicts.items()
        }
        names = sorted(raw)
        base = names[0]
        for other in names[1:]:
            for a, b, what in zip(raw[base], raw[other], ("keys", "vals", "counts")):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"range {what}: {base} vs {other}"
                )
    return dicts
