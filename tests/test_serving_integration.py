"""LSM-backed paged-KV page table + data-pipeline dedup (paper integration)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import PipelineConfig, dedup_batch, make_batch, pipeline_init
from repro.serve.kvcache import (
    PageTableConfig,
    pt_allocate,
    pt_compact,
    pt_evict,
    pt_init,
    pt_lookup,
    pt_maintain,
    pt_seq_page_count,
    pt_seq_pages,
)

CFG = PageTableConfig(num_pages=128, update_batch=16, num_levels=6)


def _alloc(state, seqs, pages):
    b = CFG.update_batch
    n = len(seqs)
    seq_ids = jnp.asarray(np.resize(np.array(seqs, np.int32), b))
    page_idxs = jnp.asarray(np.resize(np.array(pages, np.int32), b))
    valid = jnp.asarray(np.arange(b) < n)
    return pt_allocate(CFG, state, seq_ids, page_idxs, valid)


class TestPageTable:
    def test_allocate_and_translate(self):
        state = pt_init(CFG)
        state, slots = _alloc(state, [1, 1, 1, 2], [0, 1, 2, 0])
        f, s = pt_lookup(CFG, state, jnp.asarray([1, 1, 1, 2]), jnp.asarray([0, 1, 2, 0]))
        assert bool(f.all())
        np.testing.assert_array_equal(np.asarray(s), np.asarray(slots)[:4])
        # unknown page
        f, _ = pt_lookup(CFG, state, jnp.asarray([9]), jnp.asarray([0]))
        assert not bool(f[0])

    def test_slots_unique(self):
        state = pt_init(CFG)
        state, slots = _alloc(state, [1] * 8, list(range(8)))
        s = np.asarray(slots)[:8]
        assert len(set(s.tolist())) == 8

    def test_evict_frees_and_hides(self):
        state = pt_init(CFG)
        state, slots = _alloc(state, [1, 1, 2, 2], [0, 1, 0, 1])
        free_before = int(state.free_count)
        b = CFG.update_batch
        seqs = jnp.asarray(np.resize(np.array([1, 1], np.int32), b))
        pages = jnp.asarray(np.resize(np.array([0, 1], np.int32), b))
        valid = jnp.asarray(np.arange(b) < 2)
        state = pt_evict(CFG, state, seqs, pages, valid)
        assert int(state.free_count) == free_before + 2
        f, _ = pt_lookup(CFG, state, jnp.asarray([1, 1, 2]), jnp.asarray([0, 1, 0]))
        np.testing.assert_array_equal(np.asarray(f), [False, False, True])

    def test_count_and_range_enumerate_pages(self):
        state = pt_init(CFG)
        state, _ = _alloc(state, [3] * 5 + [4] * 2, [0, 1, 2, 3, 4, 0, 1])
        c, ok = pt_seq_page_count(CFG, state, jnp.asarray([3, 4, 5]), max_candidates=64)
        assert bool(ok.all())
        np.testing.assert_array_equal(np.asarray(c), [5, 2, 0])
        pages, slots, counts, ok = pt_seq_pages(
            CFG, state, jnp.asarray([3]), max_pages=8, max_candidates=64
        )
        assert bool(ok.all()) and int(counts[0]) == 5
        np.testing.assert_array_equal(np.asarray(pages[0][:5]), [0, 1, 2, 3, 4])

    def test_compact_preserves_translations(self):
        state = pt_init(CFG)
        state, _ = _alloc(state, [1, 2, 3], [0, 0, 0])
        b = CFG.update_batch
        state = pt_evict(CFG, state,
                         jnp.asarray(np.resize(np.array([2], np.int32), b)),
                         jnp.zeros((b,), jnp.int32),
                         jnp.asarray(np.arange(b) < 1))
        f1, s1 = pt_lookup(CFG, state, jnp.asarray([1, 2, 3]), jnp.zeros(3, jnp.int32))
        state = pt_compact(CFG, state)
        f2, s2 = pt_lookup(CFG, state, jnp.asarray([1, 2, 3]), jnp.zeros(3, jnp.int32))
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(
            np.where(np.asarray(f1), np.asarray(s1), -1),
            np.where(np.asarray(f2), np.asarray(s2), -1),
        )
        assert int(state.lsm.r) <= 1  # cleanup shrank the structure

    def test_maintain_keeps_translations_exact_under_churn(self):
        # Two page tables driven by the identical admission/eviction churn:
        # one plain, one with piggybacked maintenance AND an explicit
        # pt_maintain between steps. Translations must be indistinguishable.
        cfg_m = PageTableConfig(num_pages=128, update_batch=16, num_levels=6,
                                maintenance_budget=3 * 16)
        plain, maint = pt_init(CFG), pt_init(cfg_m)
        rng = np.random.default_rng(7)
        b = CFG.update_batch
        for step in range(6):
            seqs = rng.integers(1, 5, b).astype(np.int32)
            pages = rng.integers(0, 8, b).astype(np.int32)
            valid = jnp.asarray(np.arange(b) < 12)
            sj, pj = jnp.asarray(seqs), jnp.asarray(pages)
            plain, _ = pt_allocate(CFG, plain, sj, pj, valid)
            maint, _ = pt_allocate(cfg_m, maint, sj, pj, valid)
            if step % 2:
                plain = pt_evict(CFG, plain, sj, pj, valid)
                maint = pt_evict(cfg_m, maint, sj, pj, valid)
            maint = pt_maintain(cfg_m, maint)
        qs = jnp.asarray(np.repeat(np.arange(1, 5, dtype=np.int32), 8))
        qp = jnp.asarray(np.tile(np.arange(8, dtype=np.int32), 4))
        f1, s1 = pt_lookup(CFG, plain, qs, qp)
        f2, s2 = pt_lookup(cfg_m, maint, qs, qp)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(
            np.where(np.asarray(f1), np.asarray(s1), -1),
            np.where(np.asarray(f2), np.asarray(s2), -1),
        )
        assert int(maint.free_count) == int(plain.free_count)
        # the piggyback + explicit sweeps kept the affordable prefix clean
        assert int(np.asarray(maint.lsm.lvl_debt)[:2].sum()) == 0


class TestPipeline:
    def test_deterministic_batches(self):
        cfg = PipelineConfig(vocab_size=128, seq_len=16, batch_per_shard=8)
        b1 = make_batch(cfg, shard=0, step=3)
        b2 = make_batch(cfg, shard=0, step=3)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        b3 = make_batch(cfg, shard=1, step=3)
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_dedup_catches_replayed_batch(self):
        cfg = PipelineConfig(vocab_size=128, seq_len=16, batch_per_shard=8)
        state = pipeline_init(cfg)
        batch = make_batch(cfg, shard=0, step=0)
        state, out, n0 = dedup_batch(cfg, state, batch, shard=0, step=0)
        assert int(n0) == 0
        # replay the exact same batch: every document is now a duplicate
        state, out, n1 = dedup_batch(cfg, state, batch, shard=0, step=1)
        assert int(n1) == cfg.batch_per_shard
        # replaced rows differ from the originals
        assert not np.array_equal(np.asarray(out["tokens"]), np.asarray(batch["tokens"]))
