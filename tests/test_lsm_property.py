"""Property-based tests: the LSM against a Python-dict oracle (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    LSMConfig,
    lsm_init,
    lsm_update_mixed,
    lsm_lookup,
    lsm_count,
    lsm_range,
    lsm_cleanup,
)

B = 8
CFG = LSMConfig(batch_size=B, num_levels=4)
KEY_SPACE = 64  # small space => lots of duplicate/delete interaction


@st.composite
def batches(draw):
    """A sequence of mixed update batches with unique keys per batch."""
    n_batches = draw(st.integers(1, 10))
    out = []
    for _ in range(n_batches):
        keys = draw(
            st.lists(st.integers(0, KEY_SPACE - 1), min_size=B, max_size=B, unique=True)
        )
        vals = draw(st.lists(st.integers(0, 10_000), min_size=B, max_size=B))
        dels = draw(st.lists(st.booleans(), min_size=B, max_size=B))
        out.append((keys, vals, dels))
    return out


def _apply_model(model, batch):
    keys, vals, dels = batch
    for k, v, d in zip(keys, vals, dels):
        if d:
            model.pop(k, None)
        else:
            model[k] = v
    return model


def _apply_lsm(state, batch, cleanup=False):
    keys, vals, dels = batch
    state = lsm_update_mixed(
        CFG, state, jnp.array(keys), jnp.array(vals), jnp.array(dels, dtype=bool)
    )
    if cleanup:
        state = lsm_cleanup(CFG, state)
    return state


@settings(max_examples=25, deadline=None)
@given(batches(), st.booleans())
def test_lookup_matches_dict_oracle(bs, do_cleanup):
    model = {}
    state = lsm_init(CFG)
    for i, batch in enumerate(bs):
        model = _apply_model(model, batch)
        state = _apply_lsm(state, batch, cleanup=do_cleanup and i % 3 == 2)
    assert not bool(state.overflowed)
    queries = jnp.arange(KEY_SPACE)
    found, vals = lsm_lookup(CFG, state, queries)
    for k in range(KEY_SPACE):
        if k in model:
            assert bool(found[k]), f"key {k} missing"
            assert int(vals[k]) == model[k], f"key {k}: {int(vals[k])} != {model[k]}"
        else:
            assert not bool(found[k]), f"key {k} spuriously found"


@settings(max_examples=20, deadline=None)
@given(batches(), st.integers(0, KEY_SPACE - 1), st.integers(0, KEY_SPACE - 1))
def test_count_and_range_match_dict_oracle(bs, a, b):
    k1, k2 = min(a, b), max(a, b)
    model = {}
    state = lsm_init(CFG)
    for batch in bs:
        model = _apply_model(model, batch)
        state = _apply_lsm(state, batch)
    expected = sorted(k for k in model if k1 <= k <= k2)

    max_cand = CFG.capacity  # can never overflow
    counts, ok = lsm_count(CFG, state, jnp.array([k1]), jnp.array([k2]), max_cand)
    assert bool(ok[0])
    assert int(counts[0]) == len(expected)

    keys, vals, cnts, ok = lsm_range(
        CFG, state, jnp.array([k1]), jnp.array([k2]), max_cand, KEY_SPACE
    )
    assert bool(ok[0]) and int(cnts[0]) == len(expected)
    got = np.asarray(keys[0][: len(expected)])
    np.testing.assert_array_equal(got, np.array(expected))
    for i, k in enumerate(expected):
        assert int(vals[0][i]) == model[k]


@settings(max_examples=15, deadline=None)
@given(batches())
def test_cleanup_is_query_transparent(bs):
    state = lsm_init(CFG)
    for batch in bs:
        state = _apply_lsm(state, batch)
    cleaned = lsm_cleanup(CFG, state)
    queries = jnp.arange(KEY_SPACE)
    f1, v1 = lsm_lookup(CFG, state, queries)
    f2, v2 = lsm_lookup(CFG, cleaned, queries)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(
        np.where(np.asarray(f1), np.asarray(v1), 0), np.where(np.asarray(f2), np.asarray(v2), 0)
    )
