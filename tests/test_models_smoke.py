"""Per-architecture smoke tests: reduced configs, one fwd/train/prefill/decode
step on CPU, asserting output shapes and absence of NaNs (assignment item f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import model_zoo as zoo

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    st = S - cfg.num_patches if cfg.has_vision_stub else S
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
        "labels": jnp.array(rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32),
    }
    if cfg.has_vision_stub:
        batch["patch_embeds"] = jnp.array(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.bfloat16
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.array(rng.normal(size=(B, 16, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def params_cache():
    return {}


def _params(cfg, params_cache):
    if cfg.name not in params_cache:
        params_cache[cfg.name] = zoo.init_params(cfg, jax.random.PRNGKey(0))
    return params_cache[cfg.name]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_forward_shapes_and_finite(arch, params_cache):
    cfg = get_smoke_config(arch)
    params = _params(cfg, params_cache)
    batch = _batch(cfg)
    logits, aux = zoo.apply_train(cfg, params, batch)
    st = batch["tokens"].shape[1]
    assert logits.shape == (B, st, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/inf in logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss_shape(arch, params_cache):
    """One grad step runs and produces finite grads."""
    cfg = get_smoke_config(arch)
    params = _params(cfg, params_cache)
    batch = _batch(cfg)

    def loss_fn(p):
        logits, aux = zoo.apply_train(cfg, p, batch)
        lf = logits.astype(jnp.float32)
        ll = jax.nn.log_softmax(lf, axis=-1)
        nll = -jnp.take_along_axis(ll, batch["labels"][..., None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_parallel_forward(arch, params_cache):
    """Decode path correctness: prefill(S-1) + decode == train forward at last pos."""
    cfg = get_smoke_config(arch)
    params = _params(cfg, params_cache)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    st = tokens.shape[1]

    # Full parallel forward — logits at position st-1 predict token st.
    logits_all, _ = zoo.apply_train(cfg, params, batch)

    n_prefix = cfg.num_patches if cfg.has_vision_stub else 0
    prefill_batch = dict(batch)
    prefill_batch.pop("labels")
    prefill_batch["tokens"] = tokens[:, : st - 1]
    logits_pre, caches = zoo.apply_prefill(
        cfg, params, prefill_batch, cache_pad_to=st + n_prefix
    )
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_all[:, st - 2], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # One decode step with the last token must reproduce the last-position logits.
    cache_len = jnp.asarray(st - 1 + n_prefix, jnp.int32)
    logits_dec, _ = zoo.apply_decode(cfg, params, tokens[:, st - 1 :], caches, cache_len)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_all[:, st - 1], np.float32),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_plan(arch):
    """The FULL config builds a valid plan + abstract params (no allocation)."""
    from repro.configs.base import get_config
    from repro.models.transformer import decoder_plan

    cfg = get_config(arch)
    plan = decoder_plan(cfg)
    n_layers = sum(count * len(descs) for count, descs in plan)
    assert n_layers == cfg.num_layers
    n = zoo.count_params_analytic(cfg)
    assert n > 0
