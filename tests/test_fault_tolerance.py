"""Checkpoint/restart, async save, elastic restore, straggler monitor,
gradient compression, and the supervised training loop (failure injection)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.dist.compression import compressed_tree_psum, init_error_state
from repro.dist.fault_tolerance import StragglerMonitor, TrainSupervisor


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16), jnp.float32),
        "nested": {"b": jnp.arange(16, dtype=jnp.int32), "s": jnp.asarray(3, jnp.int32)},
    }


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        tree = _tree()
        cm.save(7, tree)
        spec = jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
        out = cm.restore(7, spec)
        for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_retention_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            cm.save(s, _tree())
        assert cm.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=3, async_save=True)
        cm.save(1, _tree())
        cm.wait()
        assert cm.latest_step() == 1

    def test_atomicity_no_tmp_left(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(5, _tree())
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_shape_mismatch_raises(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, _tree())
        bad = jax.tree_util.tree_map(lambda l: jax.ShapeDtypeStruct((l.shape[0] + 1,) + l.shape[1:] if l.ndim else (2,), l.dtype), _tree())
        with pytest.raises((ValueError, KeyError)):
            cm.restore(1, bad)


class TestSupervisor:
    def test_restart_after_injected_failure(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=3)
        sup = TrainSupervisor(cm, save_every=2, max_restarts=2)
        fail_at = {5}

        def step_fn(state, step):
            if step in fail_at:
                fail_at.clear()  # fail once
                raise RuntimeError("injected node failure")
            return {"x": state["x"] + 1}

        state0 = {"x": jnp.zeros((), jnp.int32)}
        final, done = sup.run(state0, step_fn, num_steps=8)
        assert done == 8
        assert int(final["x"]) == 8  # restart replays steps 4..: value consistent
        assert sup.restarts == 1
        assert any("FAILURE" in line for line in sup.log)

    def test_straggler_monitor_flags(self):
        mon = StragglerMonitor(alpha=0.5, threshold=2.0)
        assert not mon.observe(1.0)
        assert not mon.observe(1.1)
        assert mon.observe(10.0)
        assert mon.flagged_steps == 1


class TestCompression:
    def test_compressed_psum_matches_mean(self):
        if len(jax.devices()) < 1:
            pytest.skip("needs a device")
        from jax.sharding import PartitionSpec as P

        from repro.compat import AxisType, make_mesh, shard_map

        mesh = make_mesh((1,), ("d",), axis_types=(AxisType.Auto,))
        g = jax.random.normal(jax.random.PRNGKey(0), (64,), jnp.float32)
        tree = {"g": g}
        err = init_error_state(tree)

        def body(t, e):
            return compressed_tree_psum(t, "d", e)

        f = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                      check_vma=False)
        mean, new_err = f(tree, err)
        # single shard: mean == dequantized value; error feedback captures residual
        np.testing.assert_allclose(
            np.asarray(mean["g"]) + np.asarray(new_err["g"]), np.asarray(g), rtol=0, atol=1e-5
        )
        # quantization error bounded by scale/2
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(new_err["g"]))) <= scale * 0.5 + 1e-6

    def test_error_feedback_converges(self):
        """Repeated compression of a constant gradient averages to the truth."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import AxisType, make_mesh, shard_map

        mesh = make_mesh((1,), ("d",), axis_types=(AxisType.Auto,))
        g = {"g": jnp.asarray([0.001, -1.0, 0.5, 0.3333], jnp.float32)}
        err = init_error_state(g)
        f = shard_map(lambda t, e: compressed_tree_psum(t, "d", e), mesh=mesh,
                      in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False)
        acc = np.zeros(4, np.float32)
        for i in range(64):
            mean, err = f(g, err)
            acc += np.asarray(mean["g"])
        np.testing.assert_allclose(acc / 64, np.asarray(g["g"]), atol=1e-3)
