"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Each kernel is swept over shapes and key distributions and checked exactly
(integer data => bitwise equality, not allclose-with-tolerance)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref, ops, merge_path, bitonic_sort, lsm_lookup
from repro.core import semantics as sem

RNG = np.random.default_rng(42)


def _sorted_run(n, key_hi, tombstone_frac=0.2):
    keys = np.sort(RNG.integers(0, key_hi, n)).astype(np.int32)
    status = (RNG.random(n) > tombstone_frac).astype(np.int32)
    kv = np.sort(((keys << 1) | status).astype(np.int32))
    val = RNG.integers(0, 1 << 20, n).astype(np.int32)
    return jnp.array(kv), jnp.array(val)


# ---------------------------------------------------------------------------
# merge_path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("na,nb", [(256, 256), (256, 512), (512, 256), (1024, 1024), (2048, 256)])
@pytest.mark.parametrize("key_hi", [8, 1000, 1 << 20])
def test_merge_path_matches_ref(na, nb, key_hi):
    a_kv, a_val = _sorted_run(na, key_hi)
    b_kv, b_val = _sorted_run(nb, key_hi)
    rkv, rval = ref.merge_ref(a_kv, a_val, b_kv, b_val)
    pkv, pval = merge_path.merge_path(a_kv, a_val, b_kv, b_val, interpret=True)
    np.testing.assert_array_equal(np.asarray(rkv), np.asarray(pkv))
    np.testing.assert_array_equal(np.asarray(rval), np.asarray(pval))


def test_merge_path_ties_newer_first():
    # all-equal original keys: every output element of `a` must precede `b`'s
    n = merge_path.BLOCK
    a_kv = jnp.full((n,), (5 << 1) | 1, jnp.int32)
    b_kv = jnp.full((n,), (5 << 1) | 1, jnp.int32)
    a_val = jnp.arange(n, dtype=jnp.int32)
    b_val = jnp.arange(n, dtype=jnp.int32) + 10_000
    pkv, pval = merge_path.merge_path(a_kv, a_val, b_kv, b_val, interpret=True)
    np.testing.assert_array_equal(np.asarray(pval[:n]), np.arange(n))
    np.testing.assert_array_equal(np.asarray(pval[n:]), np.arange(n) + 10_000)


def test_merge_path_compare_full_sorts_by_key_variable():
    n = merge_path.BLOCK
    a_kv = jnp.sort(jnp.array(RNG.integers(0, 100, n).astype(np.int32)))
    b_kv = jnp.sort(jnp.array(RNG.integers(0, 100, n).astype(np.int32)))
    a_val = jnp.zeros(n, jnp.int32)
    b_val = jnp.ones(n, jnp.int32)
    pkv, _ = merge_path.merge_path(a_kv, a_val, b_kv, b_val, compare_full=True, interpret=True)
    assert (np.diff(np.asarray(pkv)) >= 0).all()


def test_merge_partition_boundaries():
    a = jnp.array([1, 3, 5, 7], jnp.int32)
    b = jnp.array([2, 4, 6, 8], jnp.int32)
    d = jnp.arange(9, dtype=jnp.int32)
    bounds = np.asarray(merge_path.merge_partition(a, b, d))
    # merged: 1 2 3 4 5 6 7 8 -> a-counts 0 1 1 2 2 3 3 4 4
    np.testing.assert_array_equal(bounds, [0, 1, 1, 2, 2, 3, 3, 4, 4])


# ---------------------------------------------------------------------------
# bitonic_sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 64, 1024, 2048, 4096])
@pytest.mark.parametrize("key_hi", [4, 1 << 16, 1 << 30])
def test_bitonic_sort_matches_ref(n, key_hi):
    kv = jnp.array(RNG.integers(0, key_hi, n).astype(np.int32))
    val = jnp.arange(n, dtype=jnp.int32)
    rkv, rval = ref.sort_ref(kv, val)
    pkv, pval = bitonic_sort.bitonic_sort_pairs(kv, val, interpret=True)
    np.testing.assert_array_equal(np.asarray(rkv), np.asarray(pkv))
    # bitonic is not stable: values must agree as (key, value) pair multisets
    pr = sorted(zip(np.asarray(rkv).tolist(), np.asarray(rval).tolist()))
    pp = sorted(zip(np.asarray(pkv).tolist(), np.asarray(pval).tolist()))
    assert pr == pp


# ---------------------------------------------------------------------------
# lsm_lookup (streamed lower bound)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2048, 4096, 8192])
@pytest.mark.parametrize("q", [256, 512])
def test_lower_bound_streamed_matches_ref(n, q):
    keys = jnp.sort(jnp.array(RNG.integers(0, 1 << 20, n).astype(np.int32)))
    queries = jnp.array(RNG.integers(0, 1 << 20, q).astype(np.int32))
    r = ref.lower_bound_ref(keys, queries)
    p = lsm_lookup.lower_bound_streamed(keys, queries, interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


def test_lower_bound_streamed_hits_every_boundary():
    keys = jnp.array(np.repeat(np.arange(8) * 4, 256).astype(np.int32))
    queries = jnp.array(np.arange(256).astype(np.int32) % 36)
    r = ref.lower_bound_ref(keys, queries)
    p = lsm_lookup.lower_bound_streamed(keys, queries, interpret=True)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


@pytest.mark.parametrize("n,q", [(2048, 256), (4096, 512)])
def test_upper_bound_dispatch_uses_lower_bound_kernel(n, q):
    """ops.upper_bound(k) == lower_bound(k+1) through the Pallas kernel must
    match the reference, including duplicate runs, the INT32_MAX guard lane,
    and placebo-tail keys."""
    keys = np.sort(RNG.integers(0, 1 << 16, n - 256)).astype(np.int32)
    keys = np.concatenate([keys, np.full(256, sem.PLACEBO_KEY, np.int32)])  # placebo tail
    queries = RNG.integers(0, 1 << 16, q).astype(np.int32)
    queries[:4] = [0, sem.MAX_USER_KEY, sem.PLACEBO_KEY, np.iinfo(np.int32).max]
    r = ref.upper_bound_ref(jnp.array(keys), jnp.array(queries))
    ops.set_backend("pallas")
    try:
        p = ops.upper_bound(jnp.array(keys), jnp.array(queries))
    finally:
        ops.set_backend("xla")
    np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


def test_sort_pairs_recency_newest_first_within_equal_keys():
    """The write-buffer batch-formation rule: ascending original key, later
    lane first within equal keys (even across the status-bit boundary),
    placebos last."""
    kv = jnp.array([
        (5 << 1) | 1,   # lane 0: insert 5
        (3 << 1) | 1,   # lane 1: insert 3
        (5 << 1) | 0,   # lane 2: tombstone 5 (newer than lane 0)
        sem.PLACEBO_KV, # lane 3: padding
        (5 << 1) | 1,   # lane 4: insert 5 (newest)
    ], jnp.int32)
    val = jnp.array([50, 30, 0, 0, 55], jnp.int32)
    skv, sval = ops.sort_pairs_recency(kv, val)
    np.testing.assert_array_equal(
        np.asarray(sem.original_key(skv)), [3, 5, 5, 5, sem.PLACEBO_KEY]
    )
    # within the key-5 segment: lane 4 (insert 55), lane 2 (tombstone), lane 0
    np.testing.assert_array_equal(np.asarray(sval[1:4]), [55, 0, 50])
    assert bool(sem.is_tombstone(skv[2:3])[0])


# ---------------------------------------------------------------------------
# ops dispatch: pallas backend end-to-end through the LSM
# ---------------------------------------------------------------------------


def test_lsm_update_with_pallas_backend_matches_xla():
    from repro.core import LSMConfig, lsm_init, lsm_insert, lsm_lookup as lsm_lookup_fn

    cfg = LSMConfig(batch_size=merge_path.BLOCK, num_levels=3)
    rng = np.random.default_rng(7)
    batches = [rng.choice(1 << 16, merge_path.BLOCK, replace=False) for _ in range(3)]

    states = {}
    for backend in ("xla", "pallas"):
        ops.set_backend(backend)
        try:
            st = lsm_init(cfg)
            for i, ks in enumerate(batches):
                st = lsm_insert(cfg, st, jnp.array(ks), jnp.array(ks % 997))
            states[backend] = st
        finally:
            ops.set_backend("xla")
    q = jnp.array(batches[0][:128])
    f1, v1 = lsm_lookup_fn(cfg, states["xla"], q)
    f2, v2 = lsm_lookup_fn(cfg, states["pallas"], q)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
