"""DictionaryServer: coalescing differential, tenant namespacing, policies.

The load-bearing test is the differential: a multi-tenant op trace replayed
through the coalescing server must produce per-tenant results bit-identical
to replaying each tenant call-at-a-time on its own private Dictionary —
coalescing, lane padding, scheduling order, and namespace packing must all be
observationally invisible. Runs for lsm, sorted_array, and lsm_sharded
(conftest forces 4 host devices).
"""

import numpy as np
import pytest

from repro.api import Dictionary, KeyDomainError, QueryPlan
from repro.core import semantics as sem
from repro.serve.server import DictionaryServer, ServerConfig
from repro.serve.traffic import (
    TrafficGen,
    make_trace,
    replay_direct,
    replay_oracle,
    replay_server,
)

BACKENDS = [
    pytest.param({"backend": "lsm", "num_levels": 8}, id="lsm"),
    pytest.param({"backend": "sorted_array", "capacity": 4096}, id="sorted_array"),
    pytest.param({"backend": "lsm_sharded", "num_levels": 8, "num_shards": 2},
                 id="lsm_sharded"),
]


def _assert_results_equal(trace, got, want):
    assert len(got) == len(want) == len(trace)
    for i, (g, w) in enumerate(zip(got, want)):
        op = trace[i]
        if op.kind == "update":
            assert g == w, f"op{i} update lanes"
        elif op.kind == "lookup":
            np.testing.assert_array_equal(g[0], w[0], err_msg=f"op{i} found")
            np.testing.assert_array_equal(g[1], w[1], err_msg=f"op{i} values")
        elif op.kind == "count":
            np.testing.assert_array_equal(g[0], w[0], err_msg=f"op{i} counts")
            np.testing.assert_array_equal(g[1], w[1], err_msg=f"op{i} ok")
        else:  # range: server slices rows to the op's own max_results
            mr = op.max_results
            np.testing.assert_array_equal(g[2], w[2], err_msg=f"op{i} range counts")
            np.testing.assert_array_equal(g[3], w[3], err_msg=f"op{i} range ok")
            np.testing.assert_array_equal(g[0], w[0][:, :mr], err_msg=f"op{i} range keys")
            np.testing.assert_array_equal(g[1], w[1][:, :mr], err_msg=f"op{i} range vals")


class TestDifferential:
    @pytest.mark.parametrize("opts", BACKENDS)
    @pytest.mark.parametrize("mix", ["decode_trickle", "mixed"])
    def test_server_matches_per_tenant_direct(self, opts, mix):
        tenants, trace = make_trace(
            mix, num_tenants=4, key_space=256, events=24, seed=11)
        cfg = ServerConfig(batch_size=64, **opts)
        srv = DictionaryServer(cfg)
        for t in tenants:
            srv.register_tenant(t, key_space=256)
        got = replay_server(srv, trace, step_every=16)
        want = replay_direct(cfg.make_dictionary, tenants, trace)
        _assert_results_equal(trace, got, want)

    def test_end_state_matches_oracle(self):
        """After a draining replay, per-tenant lookups over the whole local
        key space reproduce the python-dict oracle exactly."""
        tenants, trace = make_trace(
            "mixed", num_tenants=3, key_space=128, events=30, seed=3)
        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=8))
        for t in tenants:
            srv.register_tenant(t, key_space=128)
        replay_server(srv, trace, step_every=8)
        oracles = replay_oracle(trace)
        all_keys = np.arange(128, dtype=np.int64)
        tickets = {t: srv.submit_lookup(t, all_keys) for t in tenants}
        for t in tenants:
            found, vals = tickets[t].result()
            o = oracles.get(t, {})
            exp_found = np.array([int(k) in o for k in all_keys])
            np.testing.assert_array_equal(found, exp_found, err_msg=f"{t} found")
            exp_vals = np.array([o.get(int(k), 0) for k in all_keys])
            np.testing.assert_array_equal(
                np.where(found, vals, 0), exp_vals, err_msg=f"{t} vals")

    def test_single_step_coalesces_homogeneous_phase(self):
        """N tenants all submitting one small update = ONE device step; the
        coalescing ratio is the whole point of the server."""
        srv = DictionaryServer(ServerConfig(batch_size=256, num_levels=8))
        for i in range(8):
            srv.register_tenant(f"t{i}", key_space=64)
        for i in range(8):
            srv.submit_update(f"t{i}", np.arange(4), np.full(4, i, np.int32))
        before = srv.stats.device_steps
        srv.step()
        assert srv.stats.device_steps - before == 1
        # And the staged lanes are all visible.
        tk = [srv.submit_lookup(f"t{i}", np.arange(4)) for i in range(8)]
        for i, t in enumerate(tk):
            found, vals = t.result()
            assert found.all()
            assert (vals == i).all()
        assert srv.stats.ops_per_device_step >= 8.0


class TestTenantNamespacing:
    def test_registration_overflow_raises(self):
        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=6))
        srv.register_tenant("big", key_space=sem.MAX_USER_KEY - 100)
        with pytest.raises(KeyDomainError, match="overflow MAX_USER_KEY"):
            srv.register_tenant("straw", key_space=1024)
        # A small tenant still fits in the remaining tail.
        srv.register_tenant("small", key_space=64)

    def test_local_domain_checked_at_submit(self):
        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=6))
        srv.register_tenant("a", key_space=100)
        with pytest.raises(KeyDomainError, match="key space"):
            srv.submit_update("a", np.asarray([100]), np.asarray([1], np.int32))
        with pytest.raises(KeyDomainError, match="key space"):
            srv.submit_lookup("a", np.asarray([-1]))
        with pytest.raises(KeyDomainError, match="integers"):
            srv.submit_lookup("a", np.asarray([1.5]))
        with pytest.raises(KeyError, match="unknown tenant"):
            srv.submit_lookup("nobody", np.asarray([0]))

    def test_cross_tenant_isolation(self):
        """A's queries never see B's keys, even at identical local values."""
        srv = DictionaryServer(ServerConfig(batch_size=64, num_levels=8))
        srv.register_tenant("a", key_space=512)
        srv.register_tenant("b", key_space=512)
        keys = np.arange(0, 512, 7, dtype=np.int64)
        srv.submit_update("a", keys, (keys + 1).astype(np.int32))
        srv.submit_update("b", keys[:3], np.full(3, 99, np.int32))
        ca = srv.submit_count("a", np.asarray([0]), np.asarray([511]))
        cb = srv.submit_count("b", np.asarray([0]), np.asarray([511]))
        ra = srv.submit_range("a", np.asarray([0]), np.asarray([511]),
                              max_results=128)
        lb = srv.submit_lookup("b", keys[3:10])   # a-only keys, b's namespace
        counts_a, _ = ca.result()
        counts_b, _ = cb.result()
        assert int(counts_a[0]) == len(keys)
        assert int(counts_b[0]) == 3
        rk, rv, rc, _ = ra.result()
        assert int(rc[0]) == len(keys)
        np.testing.assert_array_equal(rk[0, : len(keys)], keys)
        np.testing.assert_array_equal(rv[0, : len(keys)], keys + 1)
        found, _ = lb.result()
        assert not found.any()

    def test_deregistration_tombstones_full_range(self):
        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=8))
        a = srv.register_tenant("a", key_space=256)
        srv.register_tenant("keep", key_space=256)
        keys = np.arange(0, 256, 5, dtype=np.int64)
        srv.submit_update("a", keys, np.ones(len(keys), np.int32))
        srv.submit_update("keep", keys, np.full(len(keys), 7, np.int32))
        srv.drain()
        size_before = int(srv.dictionary.size())
        removed = srv.deregister_tenant("a", chunk=16)   # multiple scan rounds
        assert removed == len(keys)
        assert int(srv.dictionary.size()) == size_before - len(keys)
        assert "a" not in srv.tenants
        # The freed extent is reused (first-fit) and arrives empty.
        b = srv.register_tenant("reborn", key_space=256)
        assert b.base == a.base
        c = srv.submit_count("reborn", np.asarray([0]), np.asarray([255]))
        counts, _ = c.result()
        assert int(counts[0]) == 0
        # The survivor is untouched.
        f, v = srv.submit_lookup("keep", keys).result()
        assert f.all() and (v == 7).all()

    def test_extent_reuse_after_fragmentation(self):
        """Adjacent freed extents coalesce; the high-water tail is reclaimed
        so the domain cannot be fragmented into uselessness by churn."""
        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=6))
        ts = [srv.register_tenant(f"t{i}", key_space=1000) for i in range(3)]
        for name in ("t0", "t1", "t2"):
            srv.deregister_tenant(name)
        big = srv.register_tenant("big", key_space=3000)
        assert big.base == ts[0].base


class TestAdmissionPolicy:
    def test_pending_model_exact_single_shard(self):
        """The host-side occupancy model tracks device pending() exactly for
        the single-shard lsm backend — the policy can run sync-free."""
        srv = DictionaryServer(ServerConfig(
            backend="lsm", batch_size=64, num_levels=8, flush_at_fraction=0.8))
        srv.register_tenant("a", key_space=4096)
        rng = np.random.default_rng(0)
        for i in range(12):
            n = int(rng.integers(1, 90))
            keys = rng.choice(4096, n, replace=False).astype(np.int64)
            srv.submit_update("a", keys, np.ones(n, np.int32))
            srv.step()
            assert srv.pending_estimate() == int(srv.dictionary.pending()), (
                f"model diverged after update {i}")

    def test_flush_policy_fires(self):
        srv = DictionaryServer(ServerConfig(
            backend="lsm", batch_size=64, num_levels=8, flush_at_fraction=0.5))
        srv.register_tenant("a", key_space=4096)
        srv.submit_update("a", np.arange(40, dtype=np.int64),
                          np.ones(40, np.int32))
        srv.step()
        assert srv.stats.flushes == 1          # 40 >= 0.5 * 64
        assert srv.pending_estimate() == 0
        assert int(srv.dictionary.pending()) == 0

    def test_sorted_array_never_flushes(self):
        srv = DictionaryServer(ServerConfig(
            backend="sorted_array", capacity=1024, batch_size=64,
            flush_at_fraction=0.1))
        srv.register_tenant("a", key_space=512)
        srv.submit_update("a", np.arange(50, dtype=np.int64),
                          np.ones(50, np.int32))
        srv.step()
        assert srv.stats.flushes == 0
        assert srv.pending_estimate() == 0

    def test_drain_runs_idle_maintenance(self):
        srv = DictionaryServer(ServerConfig(
            backend="lsm", batch_size=32, num_levels=8, maintenance_budget=64))
        srv.register_tenant("a", key_space=4096)
        keys = np.arange(256, dtype=np.int64)
        srv.submit_update("a", keys, np.ones(256, np.int32))
        srv.submit_update("a", keys, np.ones(256, np.int32),
                          is_delete=np.ones(256, bool))
        stats = srv.drain()
        assert stats.maintains >= 1


class TestIntrospectionHooks:
    def test_occupancy_lsm(self):
        d = Dictionary.create("lsm", batch_size=32, num_levels=8)
        assert d.buffered
        d = d.insert(np.arange(10, dtype=np.int64), np.ones(10, np.int32))
        occ = d.occupancy()
        assert int(occ.pending) == 10
        assert int(occ.resident) == 0
        d = d.flush()
        occ = d.occupancy()
        assert int(occ.pending) == 0
        assert int(occ.resident) == 32        # one padded batch resident
        assert int(occ.debt) == 0             # distinct live keys: no debt
        # Tombstones resident in a run are compaction debt.
        d = d.delete(np.arange(100, 110, dtype=np.int64)).flush()
        assert int(d.occupancy().debt) >= 10

    def test_flush_cost_tracks_cascade(self):
        b = 32
        d = Dictionary.create("lsm", batch_size=b, num_levels=8)
        assert int(d.flush_cost_estimate()) == 0   # empty buffer: free
        ks = np.arange(100, dtype=np.int64)
        d = d.insert(ks[:10], np.ones(10, np.int32))
        # r=0 -> one batch write
        assert int(d.flush_cost_estimate()) == b
        d = d.flush()                               # r=1
        d = d.insert(ks[10:20], np.ones(10, np.int32))
        # r=1 (trailing ones = 1) -> merge into level 1: cost 2b
        assert int(d.flush_cost_estimate()) == 2 * b
        d = d.flush()                               # r=2
        d = d.insert(ks[20:30], np.ones(10, np.int32))
        assert int(d.flush_cost_estimate()) == b    # r=2: no carry
        d = d.flush()                               # r=3
        d = d.insert(ks[30:40], np.ones(10, np.int32))
        assert int(d.flush_cost_estimate()) == 3 * b  # carry through two levels

    def test_occupancy_sorted_array(self):
        d = Dictionary.create("sorted_array", capacity=256, batch_size=32)
        assert not d.buffered
        d = d.insert(np.arange(10, dtype=np.int64), np.ones(10, np.int32))
        occ = d.occupancy()
        assert int(occ.pending) == 0
        assert int(occ.resident) == 10
        assert int(occ.debt) == 0
        assert int(d.flush_cost_estimate()) == 0

    def test_occupancy_sharded(self):
        d = Dictionary.create("lsm_sharded", batch_size=32, num_levels=8,
                              num_shards=2)
        assert d.buffered
        d = d.insert(np.arange(10, dtype=np.int64), np.ones(10, np.int32))
        occ = d.occupancy()
        assert int(occ.pending) == 10
        d = d.flush()
        occ = d.occupancy()
        assert int(occ.pending) == 0
        assert int(occ.resident) >= 10


class TestServerPageTable:
    def test_page_table_as_tenant(self):
        from repro.serve.kvcache import ServerPageTable

        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=8))
        pt = ServerPageTable(srv, num_pages=64, num_seqs=8)
        slots, _ = pt.allocate([1, 1, 1, 2], [0, 1, 2, 0])
        assert len(set(slots.tolist())) == 4
        found, got = pt.lookup([1, 1, 1, 2], [0, 1, 2, 0]).result()
        assert found.all()
        np.testing.assert_array_equal(got, slots)
        counts, ok = pt.seq_page_count([1, 2, 3]).result()
        assert ok.all()
        np.testing.assert_array_equal(counts, [3, 1, 0])
        pages, pslots, pcounts, _ = pt.seq_pages([1], max_pages=8).result()
        np.testing.assert_array_equal(pages[0, :3], [0, 1, 2])
        assert (pages[0, 3:] == -1).all()
        free_before = pt.free_count
        assert pt.evict([1, 1, 7], [0, 1, 0]) == 2   # seq 7 never existed
        assert pt.free_count == free_before + 2
        found, _ = pt.lookup([1, 1, 1], [0, 1, 2]).result()
        np.testing.assert_array_equal(found, [False, False, True])

    def test_page_table_coexists_with_other_tenants(self):
        from repro.serve.kvcache import ServerPageTable

        srv = DictionaryServer(ServerConfig(batch_size=64, num_levels=8))
        pt = ServerPageTable(srv, num_pages=32, num_seqs=4)
        srv.register_tenant("app", key_space=1024)
        pt.allocate([0, 1], [0, 0])
        srv.submit_update("app", np.asarray([5]), np.asarray([50], np.int32))
        c = pt.seq_page_count([0, 1])
        f = srv.submit_lookup("app", np.asarray([5]))
        counts, _ = c.result()
        np.testing.assert_array_equal(counts, [1, 1])
        found, vals = f.result()
        assert found.all() and int(vals[0]) == 50

    def test_pool_exhaustion(self):
        from repro.serve.kvcache import ServerPageTable

        srv = DictionaryServer(ServerConfig(batch_size=32, num_levels=6))
        pt = ServerPageTable(srv, num_pages=2, num_seqs=2)
        pt.allocate([0], [0])
        with pytest.raises(RuntimeError, match="exhausted"):
            pt.allocate([0, 0], [1, 2])


class TestTrafficGen:
    def test_trace_deterministic(self):
        _, a = make_trace("mixed", num_tenants=3, key_space=64, events=20, seed=5)
        _, b = make_trace("mixed", num_tenants=3, key_space=64, events=20, seed=5)
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x.tenant == y.tenant and x.kind == y.kind
            if x.keys is not None:
                np.testing.assert_array_equal(x.keys, y.keys)

    def test_keys_stay_local(self):
        gen = TrafficGen(["t"], key_space=64, seed=1, window=16)
        for op in gen.make("mixed", 40):
            for arr in (op.keys, op.k1, op.k2):
                if arr is not None:
                    assert (np.asarray(arr) >= 0).all()
                    assert (np.asarray(arr) < 64).all()

    def test_bad_mix_rejected(self):
        gen = TrafficGen(["t"], key_space=64)
        with pytest.raises(ValueError, match="unknown mix"):
            gen.make("nope", 1)
