"""Budgeted incremental maintenance (ISSUE 7): core + facade + sharded.

Pins the contracts documented in core/cleanup.py and docs/DESIGN.md §11:

  * maintain compacts the deepest level PREFIX its static budget affords and
    is observationally invisible to every query at any budget;
  * maintain(None) / maintain(>= capacity + b) degrades to full cleanup;
  * tombstones survive a prefix compaction while deeper levels hold
    residents, and are purged once the prefix covers everything;
  * per-level debt (LSMState.lvl_debt) accumulates when cascade merges
    materialize runs with shadowed duplicates, resets for compacted
    prefixes, and gates only_if_debt piggybacking;
  * maintenance never overflows and never touches the write buffer;
  * the facade exposes maintain()/maintenance_budget= with CapabilityError
    on non-maintaining backends, and the sharded backend maintains
    shard-locally.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Dictionary
from repro.api.backend import CapabilityError
from repro.core import (
    LSMConfig,
    all_runs,
    lsm_cleanup,
    lsm_debt,
    lsm_init,
    lsm_maintain,
    lsm_update,
)
from repro.core import semantics as sem
from repro.core.cleanup import maintain_prefix_level
from repro.core.queries import lookup_runs

B = 64
CFG = LSMConfig(batch_size=B, num_levels=4)  # capacity 64 * 15 = 960


def _ins_batch(keys, vals):
    kv = ((np.asarray(keys, np.int32) << 1) | 1).astype(np.int32)
    return jnp.array(kv), jnp.array(np.asarray(vals, np.int32))


def _del_batch(keys):
    kv = (np.asarray(keys, np.int32) << 1).astype(np.int32)
    return jnp.array(kv), jnp.zeros(len(keys), jnp.int32)


def _dup_heavy_state(n_batches=7, key_space=100, seed=3):
    """Apply n_batches full batches of unique-per-batch keys drawn from a
    small space: heavy cross-batch shadowing -> real compaction debt."""
    rng = np.random.default_rng(seed)
    state = lsm_init(CFG)
    oracle = {}
    for _ in range(n_batches):
        keys = rng.choice(key_space, B, replace=False)
        vals = rng.integers(1, 1000, B)
        state = lsm_update(CFG, state, *_ins_batch(keys, vals))
        for k, v in zip(keys.tolist(), vals.tolist()):
            oracle[int(k)] = int(v)
    return state, oracle


def _check_oracle(cfg, state, oracle, hi, tag):
    q = jnp.arange(hi, dtype=jnp.int32)
    found, vals = lookup_runs(all_runs(cfg, state), q)
    found, vals = np.asarray(found), np.asarray(vals)
    exp_f = np.array([k in oracle for k in range(hi)])
    np.testing.assert_array_equal(found, exp_f, err_msg=tag)
    exp_v = np.array([oracle.get(k, 0) for k in range(hi)])
    np.testing.assert_array_equal(
        np.where(found, vals, 0), np.where(exp_f, exp_v, 0), err_msg=tag
    )


class TestBudgetSelection:
    def test_prefix_level_thresholds(self):
        b = CFG.batch_size
        assert maintain_prefix_level(CFG, b - 1) == -1        # below level 0
        assert maintain_prefix_level(CFG, b) == 0             # exactly level 0
        assert maintain_prefix_level(CFG, 3 * b - 1) == 0
        assert maintain_prefix_level(CFG, 3 * b) == 1         # levels 0-1
        assert maintain_prefix_level(CFG, 7 * b) == 2
        assert maintain_prefix_level(CFG, 15 * b) == 3        # whole structure

    def test_below_b_budget_is_identity(self):
        state, _ = _dup_heavy_state()
        out = lsm_maintain(CFG, state, CFG.batch_size - 1)
        for a, b_ in zip(jax.tree_util.tree_leaves(state),
                         jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))

    def test_huge_budget_is_full_cleanup(self):
        state, _ = _dup_heavy_state()
        via_maintain = lsm_maintain(CFG, state, CFG.capacity + CFG.batch_size)
        via_none = lsm_maintain(CFG, state, None)
        via_cleanup = lsm_cleanup(CFG, state)
        for a, b_, c in zip(jax.tree_util.tree_leaves(via_maintain),
                            jax.tree_util.tree_leaves(via_none),
                            jax.tree_util.tree_leaves(via_cleanup)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


class TestMaintainSemantics:
    @pytest.mark.parametrize("budget_batches", [1, 3, 7, 15])
    def test_queries_invariant_at_every_budget(self, budget_batches):
        state, oracle = _dup_heavy_state()
        out = lsm_maintain(CFG, state, budget_batches * CFG.batch_size)
        _check_oracle(CFG, out, oracle, 110, f"budget={budget_batches}b")
        assert not bool(out.overflowed)

    def test_prefix_r_shrinks_and_debt_resets(self):
        state, _ = _dup_heavy_state()      # r == 7: levels 0,1,2 resident
        assert int(state.r) == 7
        assert int(lsm_debt(CFG, state)) > 0
        out = lsm_maintain(CFG, state, 3 * CFG.batch_size)  # prefix j=1
        # Levels 0-1 compacted (bits 0-1 of r recomputed), level 2 untouched.
        assert int(out.r) & ~0b11 == 0b100
        np.testing.assert_array_equal(np.asarray(out.lvl_debt[:2]), [0, 0])
        np.testing.assert_array_equal(
            np.asarray(out.lvl_debt[2:]), np.asarray(state.lvl_debt[2:])
        )
        np.testing.assert_array_equal(
            np.asarray(out.key_vars[2]), np.asarray(state.key_vars[2])
        )

    def test_write_buffer_untouched(self):
        state, oracle = _dup_heavy_state()
        # Stage 5 elements into the buffer, then maintain: buffer must survive.
        from repro.core import lsm_stage

        extra = np.array([901, 902, 903, 904, 905])
        kv, vals = _ins_batch(
            np.concatenate([extra, np.full(B - 5, sem.PLACEBO_KEY)]),
            np.concatenate([extra, np.zeros(B - 5)]),
        )
        kv = jnp.where(jnp.arange(B) < 5, kv, sem.PLACEBO_KV)
        state = lsm_stage(CFG, state, kv, vals, jnp.asarray(5, jnp.int32))
        for k in extra.tolist():
            oracle[int(k)] = int(k)
        out = lsm_maintain(CFG, state, 7 * CFG.batch_size)
        assert int(out.buf_n) == 5
        _check_oracle(CFG, out, oracle, 950, "buffer survives maintain")

    def test_tombstone_survives_partial_compaction(self):
        """Key lives deep (level 2); its tombstone lands in the prefix. A
        prefix-only maintain must KEEP the tombstone (covers_all false) and
        the key must stay deleted."""
        state = lsm_init(CFG)
        rng = np.random.default_rng(5)
        victim = 42
        # 4 batches -> r=4 (level 2 holds the oldest data incl. the victim).
        first = np.concatenate([[victim], rng.choice(
            np.setdiff1d(np.arange(200), [victim]), B - 1, replace=False)])
        state = lsm_update(CFG, state, *_ins_batch(first, first))
        for i in range(3):
            ks = rng.choice(np.arange(200, 500), B, replace=False)
            state = lsm_update(CFG, state, *_ins_batch(ks, ks))
        assert int(state.r) == 4
        # Tombstone the victim (placebo-padded batch) -> lands at level 0.
        tomb = np.concatenate([[victim], np.full(B - 1, sem.PLACEBO_KEY)])
        kv = jnp.array((tomb.astype(np.int32) << 1).astype(np.int32))
        kv = jnp.where(jnp.arange(B) == 0, kv, sem.PLACEBO_KV)
        state = lsm_update(CFG, state, kv, jnp.zeros(B, jnp.int32))
        assert int(state.r) == 5
        out = lsm_maintain(CFG, state, CFG.batch_size)  # level 0 only
        found, _ = lookup_runs(all_runs(CFG, out), jnp.array([victim]))
        assert not bool(np.asarray(found)[0]), "tombstone was wrongly purged"
        # Full cleanup afterwards really purges it.
        out = lsm_maintain(CFG, out, None)
        found, _ = lookup_runs(all_runs(CFG, out), jnp.array([victim]))
        assert not bool(np.asarray(found)[0])

    def test_tombstone_purged_when_prefix_covers_all(self):
        """With every resident batch inside the prefix, maintain may purge
        tombstones — matching cleanup's live-element count."""
        state = lsm_init(CFG)
        keys = np.arange(B)
        state = lsm_update(CFG, state, *_ins_batch(keys, keys))
        state = lsm_update(CFG, state, *_del_batch(keys))
        assert int(state.r) == 2  # levels 0 and 1 resident
        out = lsm_maintain(CFG, state, 3 * CFG.batch_size)  # covers r=2 prefix
        assert int(out.r) == 0  # everything annihilated
        found, _ = lookup_runs(all_runs(CFG, out), jnp.array(keys))
        assert not np.asarray(found).any()


class TestDebtTracking:
    def test_debt_accumulates_on_shadowing_and_resets_on_cleanup(self):
        state, _ = _dup_heavy_state()
        assert int(lsm_debt(CFG, state)) > 0
        clean = lsm_cleanup(CFG, state)
        assert int(lsm_debt(CFG, clean)) == 0
        np.testing.assert_array_equal(
            np.asarray(clean.lvl_debt), np.zeros(CFG.num_levels, np.int32)
        )

    def test_unique_keys_carry_no_debt(self):
        state = lsm_init(CFG)
        for i in range(3):
            ks = np.arange(i * B, (i + 1) * B)
            state = lsm_update(CFG, state, *_ins_batch(ks, ks))
        assert int(lsm_debt(CFG, state)) == 0

    def test_only_if_debt_skips_debt_free_prefix(self):
        state = lsm_init(CFG)
        for i in range(3):
            ks = np.arange(i * B, (i + 1) * B)
            state = lsm_update(CFG, state, *_ins_batch(ks, ks))
        out = lsm_maintain(CFG, state, 3 * B, only_if_debt=True)
        for a, b_ in zip(jax.tree_util.tree_leaves(state),
                         jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))

    def test_only_if_debt_fires_on_debt(self):
        state, oracle = _dup_heavy_state()
        assert int(np.asarray(state.lvl_debt[:2]).sum()) > 0
        out = lsm_maintain(CFG, state, 3 * B, only_if_debt=True)
        assert int(np.asarray(out.lvl_debt[:2]).sum()) == 0
        _check_oracle(CFG, out, oracle, 110, "only_if_debt fired")


class TestFacadeMaintenance:
    def test_capability_row(self):
        assert Dictionary.create("lsm", batch_size=B, num_levels=3) \
            .capabilities.supports_maintenance
        assert not Dictionary.create("sorted_array").capabilities.supports_maintenance
        assert not Dictionary.create("cuckoo").capabilities.supports_maintenance

    def test_unsupported_backend_raises_with_alternatives(self):
        with pytest.raises(CapabilityError, match="lsm"):
            Dictionary.create("sorted_array").maintain(128)
        with pytest.raises(CapabilityError, match="maintain"):
            Dictionary.create("cuckoo", maintenance_budget=128)

    def test_budget_validation(self):
        with pytest.raises(ValueError, match="maintenance_budget"):
            Dictionary.create("lsm", batch_size=B, num_levels=3,
                              maintenance_budget=0)
        d = Dictionary.create("lsm", batch_size=B, num_levels=3)
        with pytest.raises(ValueError, match="budget"):
            d.maintain(0)

    def test_explicit_budget_beats_configured(self):
        d = Dictionary.create("lsm", batch_size=B, num_levels=4,
                              maintenance_budget=B)
        rng = np.random.default_rng(0)
        for _ in range(7):
            ks = rng.choice(100, B, replace=False)
            d = d.insert(ks, ks + 1)
        d = d.flush()
        full = d.maintain(budget=10 ** 9)  # explicit: full cleanup
        assert int(jnp.sum(full.state.lvl_debt)) == 0
        assert int(full.state.r) == int(np.ceil(int(full.size()) / B))

    def test_piggyback_bounds_debt_under_churn(self):
        """With maintenance_budget configured, update-path piggybacking must
        keep the tracked prefix debt at zero after every call."""
        budget = 3 * B
        d = Dictionary.create("lsm", batch_size=B, num_levels=4,
                              flush_threshold=1, maintenance_budget=budget)
        rng = np.random.default_rng(1)
        oracle = {}
        for _ in range(8):
            ks = rng.choice(80, B, replace=False)
            vs = rng.integers(1, 1000, B)
            d = d.insert(ks, vs)
            for k, v in zip(ks.tolist(), vs.tolist()):
                oracle[int(k)] = int(v)
            assert int(jnp.sum(d.state.lvl_debt[:2])) == 0
        q = np.arange(90)
        found, vals = d.lookup(q)
        found = np.asarray(found)
        np.testing.assert_array_equal(found, [k in oracle for k in range(90)])

    def test_maintain_survives_pytree_roundtrip(self):
        import jax.tree_util as jtu

        d = Dictionary.create("lsm", batch_size=B, num_levels=3,
                              maintenance_budget=2 * B)
        leaves, treedef = jtu.tree_flatten(d)
        d2 = jtu.tree_unflatten(treedef, leaves)
        assert d2._maintenance_budget == 2 * B
        d2.maintain()  # must not raise


class TestShardedMaintenance:
    @pytest.mark.parametrize("num_shards", [
        pytest.param(1, id="shards1"),
        pytest.param(2, marks=pytest.mark.skipif(
            len(jax.devices()) < 2, reason="needs 2 host devices"), id="shards2"),
        pytest.param(4, marks=pytest.mark.skipif(
            len(jax.devices()) < 4, reason="needs 4 host devices"), id="shards4"),
    ])
    def test_shard_local_maintain_is_invisible(self, num_shards):
        d = Dictionary.create("lsm_sharded", batch_size=B, num_levels=4,
                              num_shards=num_shards)
        rng = np.random.default_rng(2)
        oracle = {}
        for _ in range(6):
            ks = rng.choice(200, B, replace=False).astype(np.int64)
            # Spread across the whole domain so every shard owns some keys.
            ks = ks * (sem.MAX_USER_KEY // 200)
            vs = rng.integers(1, 1000, B)
            d = d.insert(ks, vs)
            for k, v in zip(ks.tolist(), vs.tolist()):
                oracle[int(k)] = int(v)
            d = d.maintain(3 * B)
            q = np.array(sorted(oracle), dtype=np.int64)
            found, vals = d.lookup(q)
            assert np.asarray(found).all()
            np.testing.assert_array_equal(
                np.asarray(vals), [oracle[int(k)] for k in q]
            )
        assert int(d.size()) == len(oracle)
