"""Distributed (range-partitioned, shard_map) LSM vs the single-device LSM.

Runs with 4 forced host devices — tests/conftest.py sets
--xla_force_host_platform_device_count=4 before jax initializes (a
per-test-module guard runs too late: conftest's own jax import wins).
The owner_of partitioning tests are pure config math and need no devices."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LSMConfig, lsm_init, lsm_update, lsm_lookup, lsm_count
from repro.core import semantics as sem
from repro.core.distributed import (
    DistLSMConfig,
    dist_lsm_init,
    make_dist_cleanup,
    make_dist_count,
    make_dist_lookup,
    make_dist_range,
    make_dist_size,
    make_dist_update,
    owner_of,
    shard_bounds,
)

NEEDS_DEVICES = pytest.mark.skipif(
    len(jax.devices()) < 4, reason="needs 4 forced host devices"
)

B = 16


class TestOwnerOf:
    """Regression coverage for DistLSMConfig.range_size edge cases: keys at
    MAX_USER_KEY and keys straddling s*range_size - 1 / s*range_size must
    land on exactly one owner, for even and ragged partitions alike."""

    @staticmethod
    def _reference_owner(cfg, keys):
        """Modulo-free reference: the owner of k is the number of shard
        boundaries at or below it."""
        owner = np.zeros(len(keys), dtype=np.int64)
        for s in range(1, cfg.num_shards):
            owner += keys >= s * cfg.range_size
        return owner

    @staticmethod
    def _fuzz_keys(cfg, rng, n_random=512):
        keys = {0, 1, sem.MAX_USER_KEY - 1, sem.MAX_USER_KEY}
        for s in range(1, cfg.num_shards + 1):
            for d in (-1, 0, 1):
                k = s * cfg.range_size + d
                if 0 <= k <= sem.MAX_USER_KEY:
                    keys.add(k)
        keys |= {int(k) for k in rng.integers(0, sem.MAX_USER_KEY + 1, n_random)}
        return np.array(sorted(keys), dtype=np.int64)

    @pytest.mark.parametrize("num_shards", [1, 2, 3, 4, 5, 7, 8])
    def test_owner_matches_modulo_free_reference(self, num_shards):
        cfg = DistLSMConfig(local=LSMConfig(batch_size=8, num_levels=2),
                            num_shards=num_shards)
        keys = self._fuzz_keys(cfg, np.random.default_rng(num_shards))
        got = np.asarray(owner_of(cfg, keys))
        np.testing.assert_array_equal(got, self._reference_owner(cfg, keys))
        assert got.min() >= 0 and got.max() <= num_shards - 1

    @pytest.mark.parametrize("num_shards", [1, 2, 3, 4, 5, 7, 8])
    def test_every_key_covered_by_exactly_one_shard_interval(self, num_shards):
        """The [lo, hi] windows the COUNT/RANGE clipping uses (shard_bounds)
        must tile the key domain: each key inside exactly one window, and
        that window's shard must equal owner_of."""
        cfg = DistLSMConfig(local=LSMConfig(batch_size=8, num_levels=2),
                            num_shards=num_shards)
        keys = self._fuzz_keys(cfg, np.random.default_rng(100 + num_shards))
        lows, highs = zip(*(shard_bounds(cfg, s) for s in range(num_shards)))
        lows, highs = np.array(lows), np.array(highs)
        inside = (keys[:, None] >= lows[None, :]) & (keys[:, None] <= highs[None, :])
        np.testing.assert_array_equal(inside.sum(axis=1), np.ones(len(keys)))
        np.testing.assert_array_equal(
            np.argmax(inside, axis=1), np.asarray(owner_of(cfg, keys))
        )

    def test_max_user_key_owned_by_last_shard_window(self):
        for num_shards in (1, 2, 4, 6):
            cfg = DistLSMConfig(local=LSMConfig(batch_size=8, num_levels=2),
                                num_shards=num_shards)
            lo, hi = shard_bounds(cfg, num_shards - 1)
            assert lo <= sem.MAX_USER_KEY <= hi
            owner = int(np.asarray(owner_of(cfg, np.array([sem.MAX_USER_KEY])))[0])
            assert owner == num_shards - 1


@pytest.fixture()
def setup():
    # Function-scoped: make_dist_update donates its state argument, so every
    # test needs fresh buffers.
    from repro.compat import AxisType, make_mesh

    mesh = make_mesh((4,), ("shard",), axis_types=(AxisType.Auto,))
    cfg = DistLSMConfig(local=LSMConfig(batch_size=B, num_levels=4), num_shards=4)
    states = dist_lsm_init(cfg, mesh)
    return mesh, cfg, states


@NEEDS_DEVICES
def test_dist_matches_single_device_reference(setup):
    mesh, cfg, states = setup
    rng = np.random.default_rng(0)
    update = make_dist_update(cfg, mesh)
    lookup = make_dist_lookup(cfg, mesh)
    count = make_dist_count(cfg, mesh, max_candidates=cfg.local.capacity)

    # Single-device oracle with the same global batches.
    ref_cfg = LSMConfig(batch_size=B, num_levels=6)
    ref = lsm_init(ref_cfg)

    all_keys = []
    for step in range(6):
        keys = rng.choice(sem.MAX_USER_KEY, B, replace=False).astype(np.int32)
        dels = rng.random(B) < 0.25
        kv = jnp.asarray(np.where(dels, keys * 2, keys * 2 + 1).astype(np.int32))
        vals = jnp.asarray(np.where(dels, 0, keys % 997).astype(np.int32))
        states = update(states, kv, vals)
        ref = lsm_update(ref_cfg, ref, kv, vals)
        all_keys.extend(keys.tolist())

    q = jnp.asarray(np.array(all_keys + [1, 2, 3], dtype=np.int32))
    f_d, v_d = lookup(states, q)
    f_r, v_r = lsm_lookup(ref_cfg, ref, q)
    np.testing.assert_array_equal(np.asarray(f_d), np.asarray(f_r))
    np.testing.assert_array_equal(
        np.where(np.asarray(f_d), np.asarray(v_d), 0),
        np.where(np.asarray(f_r), np.asarray(v_r), 0),
    )

    k1 = jnp.asarray(np.array([0, 10_000, 0], dtype=np.int32))
    k2 = jnp.asarray(np.array([sem.MAX_USER_KEY, 20_000_000, 1000], dtype=np.int32))
    c_d, ok_d = count(states, k1, k2)
    c_r, ok_r = lsm_count(ref_cfg, ref, k1, k2, ref_cfg.capacity)
    assert bool(ok_d.all()) and bool(ok_r.all())
    np.testing.assert_array_equal(np.asarray(c_d), np.asarray(c_r))


@NEEDS_DEVICES
def test_dist_range_is_globally_sorted(setup):
    mesh, cfg, states = setup
    rng = np.random.default_rng(7)
    update = make_dist_update(cfg, mesh)
    rquery = make_dist_range(cfg, mesh, max_candidates=64, max_results=64)

    keys = rng.choice(sem.MAX_USER_KEY, B, replace=False).astype(np.int32)
    kv = jnp.asarray((keys * 2 + 1).astype(np.int32))
    states = update(states, kv, jnp.asarray(keys % 97, jnp.int32))

    k1 = jnp.zeros((2,), jnp.int32)
    k2 = jnp.full((2,), sem.MAX_USER_KEY, jnp.int32)
    out_keys, out_vals, counts, ok = rquery(states, k1, k2)
    assert bool(ok.all())
    # Assemble shard-major results for query 0: must equal sorted global keys.
    got = []
    for s in range(cfg.num_shards):
        c = int(counts[s, 0])
        got.extend(np.asarray(out_keys[s, 0, :c]).tolist())
    np.testing.assert_array_equal(np.array(got), np.sort(keys))


@NEEDS_DEVICES
def test_dist_size_counts_live_elements_across_shards(setup):
    mesh, cfg, states = setup
    update = make_dist_update(cfg, mesh)
    size = make_dist_size(cfg, mesh)
    assert int(size(states)) == 0
    keys = np.arange(B, dtype=np.int32) * 60_000_000  # spans all 4 shard ranges
    states = update(states, jnp.asarray(keys * 2 + 1), jnp.asarray(keys % 97))
    assert int(size(states)) == B
    states = update(states, jnp.asarray(keys * 2), jnp.zeros(B, jnp.int32))  # tombstones
    assert int(size(states)) == 0


@NEEDS_DEVICES
def test_dist_cleanup_local_and_transparent(setup):
    mesh, cfg, states = setup
    rng = np.random.default_rng(9)
    update = make_dist_update(cfg, mesh)
    lookup = make_dist_lookup(cfg, mesh)
    cleanup = make_dist_cleanup(cfg, mesh)

    keys = rng.choice(1000, B, replace=False).astype(np.int32)
    states = update(states, jnp.asarray(keys * 2 + 1), jnp.asarray(keys, jnp.int32))
    states = update(states, jnp.asarray(keys * 2 + 1), jnp.asarray(keys + 5, jnp.int32))
    q = jnp.asarray(keys)
    f1, v1 = lookup(states, q)
    states = cleanup(states)
    f2, v2 = lookup(states, q)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
