"""Sorted-array and cuckoo-hash baselines (paper §5.1, Table 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import semantics as sem
from repro.core.sorted_array import (
    SAConfig,
    sa_init,
    sa_bulk_build,
    sa_insert,
    sa_delete,
    sa_lookup,
    sa_count,
    sa_range,
)
from repro.core.cuckoo import CuckooConfig, cuckoo_build, cuckoo_lookup


class TestSortedArray:
    def test_build_and_lookup(self):
        cfg = SAConfig(capacity=64)
        st = sa_bulk_build(cfg, jnp.arange(16) * 2, jnp.arange(16))
        f, v = sa_lookup(cfg, st, jnp.array([0, 2, 3, 30]))
        np.testing.assert_array_equal(f, [True, True, False, True])
        np.testing.assert_array_equal(np.where(np.asarray(f), np.asarray(v), -1), [0, 1, -1, 15])

    def test_batch_insert_overwrites(self):
        cfg = SAConfig(capacity=64)
        st = sa_bulk_build(cfg, jnp.arange(8), jnp.zeros(8, jnp.int32))
        st = sa_insert(cfg, st, jnp.arange(8), jnp.arange(8) + 100)
        f, v = sa_lookup(cfg, st, jnp.arange(8))
        assert bool(f.all())
        np.testing.assert_array_equal(v, np.arange(8) + 100)

    def test_delete_via_tombstones(self):
        cfg = SAConfig(capacity=64)
        st = sa_bulk_build(cfg, jnp.arange(8), jnp.arange(8))
        st = sa_delete(cfg, st, jnp.array([0, 2, 4, 6]))
        f, _ = sa_lookup(cfg, st, jnp.arange(8))
        np.testing.assert_array_equal(f, [False, True, False, True, False, True, False, True])

    def test_count_and_range(self):
        cfg = SAConfig(capacity=64)
        st = sa_bulk_build(cfg, jnp.arange(16), jnp.arange(16) * 10)
        st = sa_delete(cfg, st, jnp.array([4, 5]))
        c, ok = sa_count(cfg, st, jnp.array([2]), jnp.array([8]), 32)
        assert bool(ok[0]) and int(c[0]) == 5  # 2,3,6,7,8
        ks, vs, cnt, ok = sa_range(cfg, st, jnp.array([2]), jnp.array([8]), 32, 8)
        np.testing.assert_array_equal(np.asarray(ks[0][:5]), [2, 3, 6, 7, 8])
        np.testing.assert_array_equal(np.asarray(vs[0][:5]), [20, 30, 60, 70, 80])

    def test_matches_lsm_query_results(self):
        from repro.core import LSMConfig, lsm_init, lsm_insert, lsm_delete, lsm_lookup

        rng = np.random.default_rng(3)
        lsm_cfg = LSMConfig(batch_size=8, num_levels=4)
        sa_cfg = SAConfig(capacity=lsm_cfg.capacity)
        lsm = lsm_init(lsm_cfg)
        sa = sa_init(sa_cfg)
        for i in range(5):
            ks = rng.choice(128, 8, replace=False)
            lsm = lsm_insert(lsm_cfg, lsm, jnp.array(ks), jnp.array(ks + 1))
            sa = sa_insert(sa_cfg, sa, jnp.array(ks), jnp.array(ks + 1))
        dels = rng.choice(128, 8, replace=False)
        lsm = lsm_delete(lsm_cfg, lsm, jnp.array(dels))
        sa = sa_delete(sa_cfg, sa, jnp.array(dels))
        q = jnp.arange(128)
        f1, v1 = lsm_lookup(lsm_cfg, lsm, q)
        f2, v2 = sa_lookup(sa_cfg, sa, q)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(
            np.where(np.asarray(f1), np.asarray(v1), 0), np.where(np.asarray(f2), np.asarray(v2), 0)
        )


class TestCuckoo:
    @pytest.mark.parametrize("n,load", [(100, 0.8), (1000, 0.8), (4000, 0.6)])
    def test_build_and_lookup(self, n, load):
        rng = np.random.default_rng(n)
        keys = rng.choice(1 << 20, n, replace=False).astype(np.int32)
        vals = (keys * 7 % 1009).astype(np.int32)
        cfg = CuckooConfig(table_size=int(n / load), max_rounds=200)
        table = cuckoo_build(cfg, jnp.array(keys), jnp.array(vals))
        assert bool(table.build_ok)
        f, v = cuckoo_lookup(cfg, table, jnp.array(keys[:512]))
        assert bool(f.all())
        np.testing.assert_array_equal(np.asarray(v), vals[:512])
        # misses
        miss = jnp.array((keys[:128] + (1 << 21)).astype(np.int32))
        f, _ = cuckoo_lookup(cfg, table, miss)
        assert not bool(f.any())
