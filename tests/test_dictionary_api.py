"""Unified `Dictionary` facade: backend parity, capabilities, key domain.

The headline property (paper Table 1): LSM and sorted-array are *the same
dictionary* behind the facade — a randomized mixed op sequence (insert /
delete / mixed update / cleanup, arbitrary non-multiple-of-b lengths) must
produce identical lookup/count/range answers from both, and both must agree
with a Python-dict oracle. Cuckoo must answer lookups and *refuse* everything
else with a CapabilityError instead of silently lacking the feature.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CapabilityError,
    Dictionary,
    KeyDomainError,
    QueryPlan,
    available_backends,
)
from repro.core import semantics as sem

B = 8
KEY_SPACE = 100


def _mk(backend):
    # Same explicit geometry for both run-based backends so explicit plans
    # and capacities line up exactly.
    if backend == "lsm":
        return Dictionary.create("lsm", batch_size=B, num_levels=5)  # capacity 248
    return Dictionary.create("sorted_array", capacity=248, batch_size=B)


PLAN = QueryPlan(max_candidates=248, max_results=32)


class TestBackendParity:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_randomized_mixed_ops_match_oracle_and_each_other(self, seed):
        rng = np.random.default_rng(seed)
        lsm, sa = _mk("lsm"), _mk("sorted_array")
        oracle = {}

        for step in range(12):
            op = rng.choice(["insert", "delete", "mixed", "cleanup"], p=[0.45, 0.2, 0.25, 0.1])
            if op == "cleanup":
                lsm, sa = lsm.cleanup(), sa.cleanup()
            else:
                n = int(rng.integers(1, 3 * B))  # deliberately not a multiple of B
                keys = rng.choice(KEY_SPACE, n, replace=False).astype(np.int32)
                vals = rng.integers(0, 1000, n).astype(np.int32)
                if op == "insert":
                    dels = np.zeros(n, bool)
                elif op == "delete":
                    dels = np.ones(n, bool)
                else:
                    dels = rng.random(n) < 0.4
                lsm = lsm.update(keys, vals, is_delete=jnp.asarray(dels))
                sa = sa.update(keys, vals, is_delete=jnp.asarray(dels))
                for k, v, t in zip(keys.tolist(), vals.tolist(), dels.tolist()):
                    if t:
                        oracle.pop(k, None)
                    else:
                        oracle[k] = v

            # lookups: all keys + some misses
            q = np.arange(KEY_SPACE, dtype=np.int32)
            fl, vl = lsm.lookup(q)
            fs, vs = sa.lookup(q)
            np.testing.assert_array_equal(np.asarray(fl), np.asarray(fs))
            np.testing.assert_array_equal(
                np.where(np.asarray(fl), np.asarray(vl), -1),
                np.where(np.asarray(fs), np.asarray(vs), -1),
            )
            exp_found = np.array([k in oracle for k in q])
            np.testing.assert_array_equal(np.asarray(fl), exp_found)
            exp_vals = np.array([oracle.get(k, -1) for k in q])
            np.testing.assert_array_equal(np.where(exp_found, np.asarray(vl), -1), exp_vals)

            # counts + sizes
            k1 = rng.integers(0, KEY_SPACE, 4).astype(np.int32)
            k2 = np.minimum(k1 + rng.integers(0, 40, 4), KEY_SPACE - 1).astype(np.int32)
            cl, okl = lsm.count(k1, k2, PLAN)
            cs, oks = sa.count(k1, k2, PLAN)
            assert bool(okl.all()) and bool(oks.all())
            np.testing.assert_array_equal(np.asarray(cl), np.asarray(cs))
            exp = [sum(1 for k in oracle if a <= k <= b) for a, b in zip(k1, k2)]
            np.testing.assert_array_equal(np.asarray(cl), exp)
            assert int(lsm.size()) == len(oracle) == int(sa.size())

            # ranges: contents, not just counts
            rkl, rvl, rcl, rokl = lsm.range(k1, k2, PLAN)
            rks, rvs, rcs, roks = sa.range(k1, k2, PLAN)
            assert bool(rokl.all()) and bool(roks.all())
            np.testing.assert_array_equal(np.asarray(rkl), np.asarray(rks))
            np.testing.assert_array_equal(np.asarray(rvl), np.asarray(rvs))
            for i, (a, b) in enumerate(zip(k1, k2)):
                exp_keys = sorted(k for k in oracle if a <= k <= b)
                got = np.asarray(rkl[i][: int(rcl[i])]).tolist()
                assert got == exp_keys
                assert np.asarray(rvl[i][: int(rcl[i])]).tolist() == [oracle[k] for k in exp_keys]

    def test_bulk_build_matches_incremental(self):
        rng = np.random.default_rng(3)
        keys = rng.choice(KEY_SPACE, 37, replace=False).astype(np.int32)  # not multiple of B
        vals = (keys * 3).astype(np.int32)
        built = _mk("lsm").bulk_build(keys, vals)
        inc = _mk("lsm").insert(keys, vals)
        q = np.arange(KEY_SPACE, dtype=np.int32)
        fb, vb = built.lookup(q)
        fi, vi = inc.lookup(q)
        np.testing.assert_array_equal(np.asarray(fb), np.asarray(fi))
        np.testing.assert_array_equal(
            np.where(np.asarray(fb), np.asarray(vb), -1),
            np.where(np.asarray(fi), np.asarray(vi), -1),
        )
        assert int(built.size()) == 37

    def test_valid_mask_lanes_are_invisible(self):
        d = _mk("lsm").update(
            np.asarray([1, 2, 3, 4]), np.asarray([10, 20, 30, 40]),
            valid=np.asarray([True, False, True, False]),
        )
        f, v = d.lookup(np.asarray([1, 2, 3, 4]))
        assert f.tolist() == [True, False, True, False]
        assert int(d.size()) == 2
        # masked lanes are compacted away: they never occupy buffer slots
        assert int(d.pending()) == 2

    def test_recency_rule_tombstone_loses_to_later_insert(self):
        """The write-buffer recency rule (docs/DESIGN.md §5): strict arrival
        order decides duplicates even across the insert/tombstone status
        boundary — unlike the paper's in-batch tombstone-first rule, which
        still governs the direct core path (test_lsm_semantics item 6)."""
        for backend in ("lsm", "sorted_array"):
            d = _mk(backend).update(
                np.asarray([5, 5]), np.asarray([0, 55]),
                is_delete=np.asarray([True, False]),
            )
            f, v = d.lookup(np.asarray([5]))
            assert bool(f[0]) and int(v[0]) == 55, backend
            d = d.update(np.asarray([5, 5]), np.asarray([66, 0]),
                         is_delete=np.asarray([False, True]))
            assert not bool(d.lookup(np.asarray([5]))[0][0]), backend

    def test_mixed_update_masked_lanes_skip_buffer(self):
        d = _mk("lsm").insert(np.asarray([1, 2]), np.asarray([10, 20])).flush()
        d = d.update(
            np.asarray([1, 2, 3]), np.asarray([0, 0, 30]),
            is_delete=np.asarray([True, True, False]),
            valid=np.asarray([True, False, True]),
        )
        assert int(d.pending()) == 2  # staged: tombstone(1) + insert(3)
        f, v = d.lookup(np.asarray([1, 2, 3]))
        assert f.tolist() == [False, True, True]
        assert int(d.size()) == 2


class TestCapabilities:
    def test_registry_lists_builtins(self):
        assert set(available_backends()) >= {
            "lsm", "lsm_sharded", "sorted_array", "cuckoo",
        }

    def test_cuckoo_lookup_works_but_ordered_queries_raise(self):
        keys = np.arange(50, dtype=np.int32)
        ck = Dictionary.create("cuckoo", capacity=64).bulk_build(keys, keys * 2)
        f, v = ck.lookup(np.asarray([7, 99]))
        assert f.tolist() == [True, False] and int(v[0]) == 14
        assert not ck.capabilities.supports_ordered_queries
        with pytest.raises(CapabilityError, match="does not support 'count'"):
            ck.count(0, 10)
        with pytest.raises(CapabilityError, match="does not support 'range'"):
            ck.range(0, 10)
        with pytest.raises(CapabilityError, match="does not support 'update'"):
            ck.insert(np.asarray([1]), np.asarray([1]))
        with pytest.raises(CapabilityError, match="does not support 'cleanup'"):
            ck.cleanup()

    def test_capability_error_names_alternatives(self):
        ck = Dictionary.create("cuckoo", capacity=16)
        with pytest.raises(CapabilityError, match="lsm"):
            ck.count(0, 1)

    def test_capability_errors_name_lsm_sharded_as_alternative(self):
        """The sharded backend has the full capability row, so every
        cuckoo-style unsupported-op error must list it among the backends
        that can (paper Table 1, now with four columns)."""
        ck = Dictionary.create("cuckoo", capacity=16)
        ops = [
            lambda: ck.count(0, 1),
            lambda: ck.range(0, 1),
            lambda: ck.cleanup(),
            lambda: ck.insert(np.asarray([1]), np.asarray([1])),
            lambda: ck.delete(np.asarray([1])),
        ]
        for op in ops:
            with pytest.raises(CapabilityError, match="lsm_sharded"):
                op()

    def test_lsm_sharded_capability_row_is_full(self):
        from repro.api import get_backend_class

        caps = get_backend_class("lsm_sharded").caps
        assert caps.supports_updates and caps.supports_deletes
        assert caps.supports_ordered_queries and caps.supports_cleanup
        assert caps.supports_bulk_build

    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError, match="unknown backend"):
            Dictionary.create("btree")


class TestKeyDomain:
    """Regression: out-of-domain keys used to alias the placebo key or flip
    sign after `key << 1` (core/semantics.py) and silently corrupt ordering."""

    @pytest.mark.parametrize("bad", [-1, sem.PLACEBO_KEY, sem.MAX_USER_KEY + 1, 1 << 31])
    def test_update_rejects_out_of_domain(self, bad):
        d = _mk("lsm")
        with pytest.raises(KeyDomainError):
            d.insert(np.asarray([1, bad], dtype=np.int64), np.asarray([0, 0]))

    def test_query_keys_are_validated_too(self):
        d = _mk("lsm")
        with pytest.raises(KeyDomainError):
            d.lookup(np.asarray([-5]))
        with pytest.raises(KeyDomainError):
            d.count(np.asarray([0]), np.asarray([sem.PLACEBO_KEY]))

    def test_masked_out_lanes_are_exempt(self):
        d = _mk("lsm")
        d = d.update(np.asarray([1, -1]), np.asarray([5, 5]),
                     valid=np.asarray([True, False]))
        f, _ = d.lookup(np.asarray([1]))
        assert bool(f[0])

    def test_max_user_key_is_accepted(self):
        d = _mk("lsm").insert(np.asarray([sem.MAX_USER_KEY]), np.asarray([9]))
        f, v = d.lookup(np.asarray([sem.MAX_USER_KEY]))
        assert bool(f[0]) and int(v[0]) == 9

    def test_float_keys_rejected(self):
        with pytest.raises(KeyDomainError, match="integer"):
            _mk("lsm").insert(np.asarray([1.5]), np.asarray([0]))

    def test_delete_validates_before_int32_wrap(self):
        """Regression: delete() used to cast to int32 before validation, so
        1 << 35 wrapped to key 0 and silently tombstoned it."""
        d = _mk("lsm").insert(np.asarray([0]), np.asarray([42]))
        with pytest.raises(KeyDomainError):
            d = d.delete(np.asarray([1 << 35], dtype=np.int64))
        f, v = d.lookup(np.asarray([0]))
        assert bool(f[0]) and int(v[0]) == 42

    def test_validate_false_skips_host_checks(self):
        d = Dictionary.create("lsm", batch_size=B, num_levels=4, validate=False)
        d = d.insert(np.asarray([1]), np.asarray([2]))  # no error paths hit
        assert bool(d.lookup(np.asarray([1]))[0][0])


class TestQueryPlan:
    def test_auto_plan_is_exact_for_small_dictionaries(self):
        p = QueryPlan().resolved(248)
        assert p.max_candidates == 248 and p.max_results == 248

    def test_auto_plan_bounds_large_dictionaries(self):
        p = QueryPlan().resolved(1 << 20)
        assert 4096 <= p.max_candidates < (1 << 20)

    def test_explicit_plan_overrides(self):
        p = QueryPlan(max_candidates=7, max_results=3).resolved(1 << 20)
        assert (p.max_candidates, p.max_results) == (7, 3)

    def test_plan_bound_covers_write_buffer_residents(self):
        """Regression: clamping plans to bare capacity made a full structure
        plus buffer residents permanently inexact — no explicit plan could
        restore ok=True. The bound must include the buffer slots."""
        d = Dictionary.create("lsm", batch_size=4, num_levels=1)  # capacity 4
        keys = np.arange(8, dtype=np.int32)
        d = d.insert(keys, keys)  # 4 flushed into the level + 4 buffer-resident
        assert not bool(d.overflowed())
        counts, ok = d.count(np.asarray([0]), np.asarray([7]))  # auto plan
        assert bool(ok[0]) and int(counts[0]) == 8
        counts, ok = d.count(np.asarray([0]), np.asarray([7]),
                             QueryPlan(max_candidates=8))  # explicit, unclamped
        assert bool(ok[0]) and int(counts[0]) == 8

    def test_truncation_is_flagged_not_silent(self):
        keys = np.arange(64, dtype=np.int32)
        d = _mk("lsm").insert(keys, keys)
        counts, ok = d.count(np.asarray([0]), np.asarray([63]),
                             QueryPlan(max_candidates=16))
        assert not bool(ok[0])  # truncated -> flagged

    def test_invalid_plan_rejected(self):
        with pytest.raises(ValueError):
            QueryPlan(max_candidates=0)


class TestFacadeMechanics:
    def test_pytree_roundtrip_preserves_backend_and_state(self):
        d = _mk("lsm").insert(np.asarray([4, 5]), np.asarray([40, 50]))
        leaves, treedef = jax.tree_util.tree_flatten(d)
        d2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert d2.backend == "lsm"
        f, v = d2.lookup(np.asarray([4, 5]))
        assert f.tolist() == [True, True] and v.tolist() == [40, 50]

    def test_executable_cache_is_shared_across_handles(self):
        from repro.api.dictionary import _EXEC_CACHE

        d1 = _mk("lsm").insert(np.asarray([1]), np.asarray([1]))
        n_before = len(_EXEC_CACHE)
        d2 = _mk("lsm").insert(np.asarray([2]), np.asarray([2]))  # same config
        assert len(_EXEC_CACHE) == n_before
        del d1, d2

    def test_multi_chunk_update_scans(self):
        # 3*B + 5 elements -> 4 chunks through one scanned executable.
        n = 3 * B + 5
        keys = np.arange(n, dtype=np.int32)
        d = _mk("lsm").insert(keys, keys * 2)
        assert int(d.size()) == n
        f, v = d.lookup(keys)
        assert bool(f.all())
        np.testing.assert_array_equal(np.asarray(v), keys * 2)

    @pytest.mark.parametrize("backend", ["lsm", "sorted_array"])
    def test_duplicate_keys_in_one_call_last_wins(self, backend):
        """Regression: within-chunk duplicates used to resolve to the OLDEST
        lane while across-chunk duplicates resolved to the newest — the
        winner depended on where the pad/split placed chunk boundaries."""
        # same chunk (n < B)
        d = _mk(backend).insert(np.asarray([5, 5]), np.asarray([111, 222]))
        assert int(d.lookup(np.asarray([5]))[1][0]) == 222
        # across chunks (n > B, duplicate straddles the boundary)
        keys = np.r_[np.asarray([5]), np.arange(B - 1) + 10, np.asarray([5])].astype(np.int32)
        vals = np.r_[np.asarray([111]), np.zeros(B - 1), np.asarray([222])].astype(np.int32)
        d = _mk(backend).insert(keys, vals)
        assert int(d.lookup(np.asarray([5]))[1][0]) == 222

    def test_empty_update_is_noop(self):
        d = _mk("lsm")
        d2 = d.update(np.zeros((0,), np.int32))
        assert d2 is d

    def test_scalar_keys_promote(self):
        d = _mk("lsm").insert(7, 70)
        f, v = d.lookup(7)
        assert bool(f[0]) and int(v[0]) == 70

    def test_overflow_is_latched_not_silent(self):
        d = Dictionary.create("lsm", batch_size=4, num_levels=1)  # capacity 4
        d = d.insert(np.asarray([1, 2, 3, 4]), np.zeros(4, np.int32))  # staged only
        assert not bool(d.overflowed())
        # Flushes the first batch (r -> max) and stages the second: the write
        # buffer grants up to b elements of grace beyond the level arenas.
        d = d.insert(np.asarray([5, 6, 7, 8]), np.zeros(4, np.int32))
        assert not bool(d.overflowed())
        # One more element forces a flush past the last batch slot: latched.
        d = d.insert(np.asarray([9]), np.zeros(1, np.int32))
        assert bool(d.overflowed())
