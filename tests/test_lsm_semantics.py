"""Batch-operation semantics of the LSM (paper §3.1 items 1-6, §3.4 invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LSMConfig,
    lsm_init,
    lsm_insert,
    lsm_delete,
    lsm_update_mixed,
    lsm_bulk_build,
    lsm_lookup,
    lsm_count,
    lsm_cleanup,
    lsm_valid_count,
    level_view,
)
from repro.core import semantics as sem

CFG = LSMConfig(batch_size=8, num_levels=4)


def _insert(state, keys, vals):
    return lsm_insert(CFG, state, jnp.asarray(keys), jnp.asarray(vals))


def test_insert_then_lookup():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8) + 100)
    found, vals = lsm_lookup(CFG, state, jnp.array([0, 3, 7, 42]))
    np.testing.assert_array_equal(found, [True, True, True, False])
    np.testing.assert_array_equal(vals[:3], [100, 103, 107])


def test_item3_most_recent_batch_wins():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.full(8, 1))
    state = _insert(state, np.arange(8), np.full(8, 2))
    found, vals = lsm_lookup(CFG, state, jnp.arange(8))
    assert bool(found.all())
    np.testing.assert_array_equal(vals, np.full(8, 2))


def test_item5_delete_hides_all_older_inserts():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 10)  # same keys again
    state = lsm_delete(CFG, state, jnp.arange(8))
    found, _ = lsm_lookup(CFG, state, jnp.arange(8))
    assert not bool(found.any())


def test_item6_insert_and_delete_same_batch_is_deleted():
    state = lsm_init(CFG)
    # key 5 both inserted and deleted within one batch
    keys = np.array([5, 5, 1, 2, 3, 4, 6, 7])
    vals = np.array([99, 0, 1, 2, 3, 4, 6, 7])
    is_del = np.array([0, 1, 0, 0, 0, 0, 0, 0], dtype=bool)
    state = lsm_update_mixed(CFG, state, jnp.array(keys), jnp.array(vals), jnp.array(is_del))
    found, _ = lsm_lookup(CFG, state, jnp.array([5]))
    assert not bool(found[0])
    found, vals_out = lsm_lookup(CFG, state, jnp.array([1, 7]))
    assert bool(found.all())
    np.testing.assert_array_equal(vals_out, [1, 7])


def test_reinsert_after_delete_is_visible():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = lsm_delete(CFG, state, jnp.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 50)
    found, vals = lsm_lookup(CFG, state, jnp.arange(8))
    assert bool(found.all())
    np.testing.assert_array_equal(vals, np.arange(8) + 50)


def test_level_occupancy_tracks_binary_counter():
    state = lsm_init(CFG)
    for r in range(1, 8):
        state = _insert(state, np.arange(8) + 100 * r, np.arange(8))
        assert int(state.r) == r
        for i in range(CFG.num_levels):
            kv, _ = level_view(CFG, state, i)
            empty = bool(jnp.all(kv == sem.PLACEBO_KV))
            expected_full = bool((r >> i) & 1)
            assert empty != expected_full, (r, i)


def test_levels_are_sorted_by_original_key():
    state = lsm_init(CFG)
    rng = np.random.default_rng(1)
    for r in range(7):
        state = _insert(state, rng.choice(1000, 8, replace=False), np.arange(8))
    for i in range(CFG.num_levels):
        kv, _ = level_view(CFG, state, i)
        orig = np.asarray(sem.original_key(kv))
        assert (np.diff(orig) >= 0).all()


def test_overflow_latches_and_preserves_state():
    state = lsm_init(CFG)
    for r in range(CFG.max_batches):
        state = _insert(state, np.arange(8) + 8 * r, np.arange(8))
    assert not bool(state.overflowed)
    from repro.core.lsm import arena_view

    before = np.asarray(arena_view(state)[0]).copy()
    state = _insert(state, np.arange(8) + 9999, np.arange(8))
    assert bool(state.overflowed)
    np.testing.assert_array_equal(before, np.asarray(arena_view(state)[0]))
    assert int(state.r) == CFG.max_batches


def test_bulk_build_matches_incremental():
    keys = np.arange(24) * 3
    vals = np.arange(24)
    st_bulk = lsm_bulk_build(CFG, jnp.array(keys), jnp.array(vals))
    st_inc = lsm_init(CFG)
    for i in range(3):
        st_inc = _insert(st_inc, keys[8 * i : 8 * i + 8], vals[8 * i : 8 * i + 8])
    q = jnp.array(list(keys) + [1, 100])
    f1, v1 = lsm_lookup(CFG, st_bulk, q)
    f2, v2 = lsm_lookup(CFG, st_inc, q)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(np.where(f1, v1, 0), np.where(f2, v2, 0))


def test_cleanup_preserves_visible_set_and_shrinks():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 10)   # duplicates
    state = lsm_delete(CFG, state, jnp.array([0, 1, 2, 3, 100, 101, 102, 103]))
    valid_before = int(lsm_valid_count(CFG, state))
    assert valid_before == 4  # keys 4..7
    cleaned = lsm_cleanup(CFG, state)
    assert int(cleaned.r) == 1  # ceil(4/8)
    q = jnp.arange(8)
    f_before, v_before = lsm_lookup(CFG, state, q)
    f_after, v_after = lsm_lookup(CFG, cleaned, q)
    np.testing.assert_array_equal(f_before, f_after)
    np.testing.assert_array_equal(np.where(f_before, v_before, 0), np.where(f_after, v_after, 0))
    c, ok = lsm_count(CFG, cleaned, jnp.array([0]), jnp.array([1000]), 64)
    assert bool(ok[0]) and int(c[0]) == 4


def test_cleanup_of_empty_lsm():
    state = lsm_cleanup(CFG, lsm_init(CFG))
    assert int(state.r) == 0
    found, _ = lsm_lookup(CFG, state, jnp.array([0]))
    assert not bool(found[0])


def test_stage_flush_core_write_buffer():
    """lsm_stage absorbs sub-batches without consuming a slot; duplicates
    resolve by arrival order (a later tombstone deletes, a later insert
    resurrects); lsm_flush pushes the buffer down query-transparently."""
    from repro.core import lsm_stage, lsm_flush

    state = lsm_init(CFG)
    # lanes: insert 3, insert 5, insert 9, tombstone 5 (later -> 5 deleted)
    keys = np.array([3, 5, 9, 5, 0, 0, 0, 0])
    dels = np.array([0, 0, 0, 1, 0, 0, 0, 0], dtype=bool)
    kv = np.where(np.arange(8) < 4, np.asarray(sem.encode(keys, dels)), sem.PLACEBO_KV)
    vals = np.array([30, 50, 90, 0, 0, 0, 0, 0], dtype=np.int32)
    state = lsm_stage(CFG, state, jnp.asarray(kv), jnp.asarray(vals), 4)
    assert int(state.buf_n) == 4 and int(state.r) == 0
    found, vals_out = lsm_lookup(CFG, state, jnp.array([3, 5, 9]))
    np.testing.assert_array_equal(found, [True, False, True])
    # a later staged insert resurrects the tombstoned key (recency rule)
    kv2 = np.full(8, sem.PLACEBO_KV, np.int32)
    kv2[0] = int(sem.encode_insert(jnp.array([5]))[0])
    v2 = np.zeros(8, np.int32)
    v2[0] = 55
    state = lsm_stage(CFG, state, jnp.asarray(kv2), jnp.asarray(v2), 1)
    found, vals_out = lsm_lookup(CFG, state, jnp.array([5]))
    assert bool(found[0]) and int(vals_out[0]) == 55
    before = lsm_lookup(CFG, state, jnp.array([3, 5, 9, 42]))
    state = lsm_flush(CFG, state)
    assert int(state.buf_n) == 0 and int(state.r) == 1
    after = lsm_lookup(CFG, state, jnp.array([3, 5, 9, 42]))
    np.testing.assert_array_equal(np.asarray(before[0]), np.asarray(after[0]))
    np.testing.assert_array_equal(np.asarray(before[1]), np.asarray(after[1]))


def test_stage_overflow_flushes_oldest_and_retains_newest():
    from repro.core import lsm_stage

    state = lsm_init(CFG)
    for i in range(3):  # 3 full-width stages of 8: last one keeps 8 pending
        keys = np.arange(8) + 8 * i
        kv = np.asarray(sem.encode_insert(jnp.asarray(keys)))
        state = lsm_stage(CFG, state, jnp.asarray(kv), jnp.arange(8) + 8 * i, 8)
    assert int(state.buf_n) == 8 and int(state.r) == 2
    found, vals = lsm_lookup(CFG, state, jnp.arange(24))
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(vals), np.arange(24))


def test_buffer_state_invariants():
    """buf_seq is the explicit arrival-order witness (seq == position, b on
    placebo lanes) and buf_sorted_* is the cached recency-sorted view —
    staging, partial flushes, and full flushes must all maintain both."""
    from repro.core import lsm_stage, lsm_flush, buffer_run
    from repro.kernels import ops as kops

    def check(state):
        n = int(state.buf_n)
        exp_seq = np.where(np.arange(8) < n, np.arange(8), 8)
        np.testing.assert_array_equal(np.asarray(state.buf_seq), exp_seq)
        skv, sval = kops.sort_pairs_recency(state.buf_kv, state.buf_val)
        np.testing.assert_array_equal(np.asarray(state.buf_sorted_kv), np.asarray(skv))
        np.testing.assert_array_equal(np.asarray(state.buf_sorted_val), np.asarray(sval))
        bkv, bval = buffer_run(CFG, state)
        np.testing.assert_array_equal(np.asarray(bkv), np.asarray(skv))

    state = lsm_init(CFG)
    check(state)
    rng = np.random.default_rng(3)
    for i in range(7):  # ragged stages: appends, partial retentions, flush
        m = int(rng.integers(1, 9))
        keys = rng.integers(0, 50, 8)
        kv = np.where(np.arange(8) < m, np.asarray(sem.encode_insert(jnp.asarray(keys))),
                      sem.PLACEBO_KV)
        state = lsm_stage(CFG, state, jnp.asarray(kv), jnp.asarray(keys % 7), m)
        check(state)
    state = lsm_flush(CFG, state)
    check(state)
    assert int(state.buf_n) == 0


def test_compact_real_masks_lanes_out_of_the_buffer():
    from repro.core import compact_real, lsm_stage

    kv = np.asarray(sem.encode_insert(jnp.arange(8)))
    mask = np.array([1, 0, 1, 0, 1, 0, 1, 0], dtype=bool)
    out_kv, out_val, cnt = compact_real(jnp.asarray(kv), jnp.arange(8), jnp.asarray(mask))
    assert int(cnt) == 4
    np.testing.assert_array_equal(
        np.asarray(sem.original_key(out_kv))[:4], [0, 2, 4, 6]
    )
    assert (np.asarray(out_kv)[4:] == sem.PLACEBO_KV).all()
    state = lsm_stage(CFG, lsm_init(CFG), out_kv, out_val, cnt)
    assert int(state.buf_n) == 4  # masked lanes never occupy buffer slots


def test_update_is_jittable_and_matches_eager():
    import functools

    state = lsm_init(CFG)
    jit_insert = jax.jit(functools.partial(lsm_insert, CFG))
    s1 = jit_insert(state, jnp.arange(8), jnp.arange(8))
    s2 = lsm_insert(CFG, state, jnp.arange(8), jnp.arange(8))
    for a, b in zip(s1.key_vars, s2.key_vars):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
