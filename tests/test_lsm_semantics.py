"""Batch-operation semantics of the LSM (paper §3.1 items 1-6, §3.4 invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    LSMConfig,
    lsm_init,
    lsm_insert,
    lsm_delete,
    lsm_update_mixed,
    lsm_bulk_build,
    lsm_lookup,
    lsm_count,
    lsm_cleanup,
    lsm_valid_count,
    level_view,
)
from repro.core import semantics as sem

CFG = LSMConfig(batch_size=8, num_levels=4)


def _insert(state, keys, vals):
    return lsm_insert(CFG, state, jnp.asarray(keys), jnp.asarray(vals))


def test_insert_then_lookup():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8) + 100)
    found, vals = lsm_lookup(CFG, state, jnp.array([0, 3, 7, 42]))
    np.testing.assert_array_equal(found, [True, True, True, False])
    np.testing.assert_array_equal(vals[:3], [100, 103, 107])


def test_item3_most_recent_batch_wins():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.full(8, 1))
    state = _insert(state, np.arange(8), np.full(8, 2))
    found, vals = lsm_lookup(CFG, state, jnp.arange(8))
    assert bool(found.all())
    np.testing.assert_array_equal(vals, np.full(8, 2))


def test_item5_delete_hides_all_older_inserts():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 10)  # same keys again
    state = lsm_delete(CFG, state, jnp.arange(8))
    found, _ = lsm_lookup(CFG, state, jnp.arange(8))
    assert not bool(found.any())


def test_item6_insert_and_delete_same_batch_is_deleted():
    state = lsm_init(CFG)
    # key 5 both inserted and deleted within one batch
    keys = np.array([5, 5, 1, 2, 3, 4, 6, 7])
    vals = np.array([99, 0, 1, 2, 3, 4, 6, 7])
    is_del = np.array([0, 1, 0, 0, 0, 0, 0, 0], dtype=bool)
    state = lsm_update_mixed(CFG, state, jnp.array(keys), jnp.array(vals), jnp.array(is_del))
    found, _ = lsm_lookup(CFG, state, jnp.array([5]))
    assert not bool(found[0])
    found, vals_out = lsm_lookup(CFG, state, jnp.array([1, 7]))
    assert bool(found.all())
    np.testing.assert_array_equal(vals_out, [1, 7])


def test_reinsert_after_delete_is_visible():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = lsm_delete(CFG, state, jnp.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 50)
    found, vals = lsm_lookup(CFG, state, jnp.arange(8))
    assert bool(found.all())
    np.testing.assert_array_equal(vals, np.arange(8) + 50)


def test_level_occupancy_tracks_binary_counter():
    state = lsm_init(CFG)
    for r in range(1, 8):
        state = _insert(state, np.arange(8) + 100 * r, np.arange(8))
        assert int(state.r) == r
        for i in range(CFG.num_levels):
            kv, _ = level_view(CFG, state, i)
            empty = bool(jnp.all(kv == sem.PLACEBO_KV))
            expected_full = bool((r >> i) & 1)
            assert empty != expected_full, (r, i)


def test_levels_are_sorted_by_original_key():
    state = lsm_init(CFG)
    rng = np.random.default_rng(1)
    for r in range(7):
        state = _insert(state, rng.choice(1000, 8, replace=False), np.arange(8))
    for i in range(CFG.num_levels):
        kv, _ = level_view(CFG, state, i)
        orig = np.asarray(sem.original_key(kv))
        assert (np.diff(orig) >= 0).all()


def test_overflow_latches_and_preserves_state():
    state = lsm_init(CFG)
    for r in range(CFG.max_batches):
        state = _insert(state, np.arange(8) + 8 * r, np.arange(8))
    assert not bool(state.overflowed)
    from repro.core.lsm import arena_view

    before = np.asarray(arena_view(state)[0]).copy()
    state = _insert(state, np.arange(8) + 9999, np.arange(8))
    assert bool(state.overflowed)
    np.testing.assert_array_equal(before, np.asarray(arena_view(state)[0]))
    assert int(state.r) == CFG.max_batches


def test_bulk_build_matches_incremental():
    keys = np.arange(24) * 3
    vals = np.arange(24)
    st_bulk = lsm_bulk_build(CFG, jnp.array(keys), jnp.array(vals))
    st_inc = lsm_init(CFG)
    for i in range(3):
        st_inc = _insert(st_inc, keys[8 * i : 8 * i + 8], vals[8 * i : 8 * i + 8])
    q = jnp.array(list(keys) + [1, 100])
    f1, v1 = lsm_lookup(CFG, st_bulk, q)
    f2, v2 = lsm_lookup(CFG, st_inc, q)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(np.where(f1, v1, 0), np.where(f2, v2, 0))


def test_cleanup_preserves_visible_set_and_shrinks():
    state = lsm_init(CFG)
    state = _insert(state, np.arange(8), np.arange(8))
    state = _insert(state, np.arange(8), np.arange(8) + 10)   # duplicates
    state = lsm_delete(CFG, state, jnp.array([0, 1, 2, 3, 100, 101, 102, 103]))
    valid_before = int(lsm_valid_count(CFG, state))
    assert valid_before == 4  # keys 4..7
    cleaned = lsm_cleanup(CFG, state)
    assert int(cleaned.r) == 1  # ceil(4/8)
    q = jnp.arange(8)
    f_before, v_before = lsm_lookup(CFG, state, q)
    f_after, v_after = lsm_lookup(CFG, cleaned, q)
    np.testing.assert_array_equal(f_before, f_after)
    np.testing.assert_array_equal(np.where(f_before, v_before, 0), np.where(f_after, v_after, 0))
    c, ok = lsm_count(CFG, cleaned, jnp.array([0]), jnp.array([1000]), 64)
    assert bool(ok[0]) and int(c[0]) == 4


def test_cleanup_of_empty_lsm():
    state = lsm_cleanup(CFG, lsm_init(CFG))
    assert int(state.r) == 0
    found, _ = lsm_lookup(CFG, state, jnp.array([0]))
    assert not bool(found[0])


def test_update_is_jittable_and_matches_eager():
    import functools

    state = lsm_init(CFG)
    jit_insert = jax.jit(functools.partial(lsm_insert, CFG))
    s1 = jit_insert(state, jnp.arange(8), jnp.arange(8))
    s2 = lsm_insert(CFG, state, jnp.arange(8), jnp.arange(8))
    for a, b in zip(s1.key_vars, s2.key_vars):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
