"""Shared pytest config.

Two process-level concerns, both of which must run before jax initializes:

* **Forced host device count.** The sharded-backend parity tests
  (test_backend_parity.py) and the distributed-LSM tests need a multi-device
  pool; on CPU that means --xla_force_host_platform_device_count. The flag
  only takes effect before the jax backend comes up, and conftest is the
  first module pytest imports, so it is set here — per-test-module guards
  run too late (conftest's own jax import wins).

* **Compilation-cache pressure.** The full suite compiles many hundreds of
  XLA CPU executables in one process; without releasing them the ORC JIT
  eventually fails with "INTERNAL: Failed to materialize symbols". Dropping
  jax's compilation caches between test modules keeps the resident
  executable count bounded.
"""

import gc
import os
import sys

if "jax" not in sys.modules and "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
    )

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
