"""Shared pytest config.

The full suite compiles many hundreds of XLA CPU executables in one process;
without releasing them the ORC JIT eventually fails with
"INTERNAL: Failed to materialize symbols". Dropping jax's compilation caches
between test modules keeps the resident executable count bounded.
"""

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
