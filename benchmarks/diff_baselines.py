"""Compare fresh BENCH_<suite>.json runs against committed baselines.

Usage:
    python -m benchmarks.diff_baselines --current bench-out \\
        [--baseline benchmarks/baselines] [--threshold 3.0] [--update]

For every suite present in BOTH directories, each row's `us_per_call` is
compared by name. A row regresses when current > threshold * baseline; the
exit code is 1 if any row regresses (the CI perf lane fails on it). New rows
(no baseline) and removed rows are reported but never fail the diff — suites
grow across PRs.

The threshold is deliberately generous (default 3.0x): shared-CI wall-clock
noise on CPU interpret/XLA paths is large, and this lane exists to catch
order-of-magnitude regressions (an accidentally quadratic path, a lost jit
cache), not single-digit percent drift. Tighten it when runners are
dedicated.

`--update` rewrites the baseline directory from the current run (the
workflow for intentional perf-profile changes: regenerate, review the JSON
diff, commit).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys


def load_suites(dir_path: str) -> dict:
    suites = {}
    for path in sorted(glob.glob(os.path.join(dir_path, "BENCH_*.json"))):
        with open(path) as f:
            payload = json.load(f)
        suites[payload["suite"]] = payload
    return suites


def diff_suite(name: str, base: dict, cur: dict, threshold: float):
    """Yield (row_name, status, detail) for one suite."""
    base_rows = {r["name"]: r for r in base["rows"]}
    cur_rows = {r["name"]: r for r in cur["rows"]}
    for row_name in sorted(set(base_rows) | set(cur_rows)):
        b, c = base_rows.get(row_name), cur_rows.get(row_name)
        if b is None:
            yield row_name, "new", f"{c['us_per_call']:.1f}us (no baseline)"
            continue
        if c is None:
            yield row_name, "removed", f"baseline was {b['us_per_call']:.1f}us"
            continue
        if b["us_per_call"] <= 0:
            yield row_name, "ok", "baseline 0us, skipped"
            continue
        ratio = c["us_per_call"] / b["us_per_call"]
        detail = (
            f"{b['us_per_call']:.1f}us -> {c['us_per_call']:.1f}us "
            f"({ratio:.2f}x)"
        )
        yield row_name, ("regressed" if ratio > threshold else "ok"), detail


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="directory with freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory with committed baseline BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=3.0,
                    help="fail when current > threshold * baseline us_per_call")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline directory from --current")
    args = ap.parse_args()

    current = load_suites(args.current)
    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for path in sorted(glob.glob(os.path.join(args.current, "BENCH_*.json"))):
            shutil.copy(path, args.baseline)
            print(f"updated {os.path.join(args.baseline, os.path.basename(path))}")
        return 0

    baseline = load_suites(args.baseline)
    if not baseline:
        print(f"no baselines in {args.baseline!r}; nothing to diff")
        return 0

    regressions = 0
    for name in sorted(set(baseline) & set(current)):
        bb, cc = baseline[name], current[name]
        if bb.get("config") != cc.get("config"):
            print(f"[{name}] config changed {bb.get('config')} -> "
                  f"{cc.get('config')}; skipping (regenerate baselines)")
            continue
        for row_name, status, detail in diff_suite(
            name, bb, cc, args.threshold
        ):
            marker = {"ok": " ", "new": "+", "removed": "-", "regressed": "!"}[status]
            print(f"[{name}] {marker} {row_name}: {detail}")
            if status == "regressed":
                regressions += 1
    missing = sorted(set(baseline) - set(current))
    if missing:
        print(f"suites not re-run (kept baselines): {missing}")
    if regressions:
        print(f"FAIL: {regressions} row(s) regressed beyond "
              f"{args.threshold:.1f}x", file=sys.stderr)
        return 1
    print("perf diff OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
