"""Continuous-batching server vs call-at-a-time facade (repro.serve.server).

The paper's rates are batched rates; a serving front end only realizes them
if something coalesces thousands of tiny client ops into device-sized
batches. This suite replays identical multi-tenant traces (serve/traffic.py)
two ways and times the whole replay, results materialized, for each traffic
archetype:

  direct: one private Dictionary per tenant, one padded device call per op —
          the adoption gap the server closes;
  server: ops queued and coalesced into per-kind device steps by
          DictionaryServer (same results, differentially tested in
          tests/test_server.py).

Rows record ops/s for both paths plus a `ratio` row per mix; the
decode-trickle + prefill-burst serving mix must show the server >= 3x the
call-at-a-time baseline (asserted, not just printed — this is the acceptance
bar for the coalescing design). Coalescing stats (ops per device step,
flushes) ride along in the derived column.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.api import QueryPlan
from repro.serve.server import DictionaryServer, ServerConfig
from repro.serve.traffic import (
    TrafficGen,
    make_trace,
    replay_direct,
    replay_server,
)


def _serving_mix_trace(num_tenants: int, key_space: int, events: int, seed: int):
    """The acceptance-bar workload: decode trickles with periodic prefill
    bursts (no storms — eviction has its own row)."""
    tenants = [f"tenant{i:03d}" for i in range(num_tenants)]
    gen = TrafficGen(tenants, key_space=key_space, seed=seed)
    ops = []
    for i in range(events):
        if i % 8 == 7:
            ops.extend(gen.prefill_burst(tenants[int(gen.rng.integers(num_tenants))]))
        else:
            ops.extend(gen.decode_trickle(tenants[i % num_tenants]))
    return tenants, ops


def _replay_pair(cfg: ServerConfig, tenants, trace, key_space: int,
                 step_every: int):
    """(server_seconds, direct_seconds, stats) for one trace, both paths
    warmed (executables compiled on a throwaway replay) before timing."""
    def run_server():
        srv = DictionaryServer(cfg)
        for t in tenants:
            srv.register_tenant(t, key_space=key_space)
        t0 = time.perf_counter()
        replay_server(srv, trace, step_every=step_every)
        return time.perf_counter() - t0, srv.stats

    def run_direct():
        t0 = time.perf_counter()
        replay_direct(cfg.make_dictionary, tenants, trace, plan=cfg.default_plan)
        return time.perf_counter() - t0

    run_server()   # warm: compiles the bucketed coalesced shapes
    run_direct()   # warm: compiles the per-op ragged shapes
    s_dt, stats = run_server()
    d_dt = run_direct()
    return s_dt, d_dt, stats


def run(num_tenants: int = 32, events: int = 320, batch_size: int = 256,
        key_space: int = 1024, step_every: int = 128, smoke: bool = False) -> None:
    # Coalescing throughput scales with concurrent tenants: the scheduler's
    # round count is bounded by one tenant's op alternation depth, so more
    # tenants widen each coalesced call while the direct path pays one
    # dispatch per op regardless.
    if smoke:
        num_tenants, events, batch_size = 16, 128, 64
        key_space, step_every = 256, 64
    # Right-size the candidate tile to the traffic's tiny windows — the
    # auto-plan sizes for full-structure scans (8k+ candidates/lane), which
    # would make every window query compute-bound in BOTH paths and bury the
    # dispatch costs this suite measures. Same plan feeds both replays.
    plan = QueryPlan(max_candidates=max(1024, 4 * key_space))
    cfg = ServerConfig(backend="lsm", batch_size=batch_size, num_levels=10,
                       maintenance_budget=None, default_plan=plan)

    ratios = {}
    mixes = ["decode_trickle", "prefill_burst", "eviction_storm", "mixed"]
    for mix in mixes:
        tenants, trace = make_trace(
            mix, num_tenants=num_tenants, key_space=key_space,
            events=events, seed=17)
        n_ops = len(trace)
        s_dt, d_dt, stats = _replay_pair(cfg, tenants, trace, key_space,
                                         step_every)
        emit(f"serve/{mix}/server", s_dt / n_ops,
             f"{n_ops / s_dt:.0f}ops/s {stats.ops_per_device_step:.1f}ops/step "
             f"flushes={stats.flushes}")
        emit(f"serve/{mix}/direct", d_dt / n_ops,
             f"{n_ops / d_dt:.0f}ops/s 1 device call/op")
        ratios[mix] = d_dt / s_dt
        emit(f"serve/{mix}/ratio", 0.0,
             f"server {ratios[mix]:.2f}x direct ({n_ops} ops, "
             f"{num_tenants} tenants)")

    # Acceptance bar: the serving steady state (decode trickles + prefill
    # bursts) through the server must beat call-at-a-time by >= 3x.
    tenants, trace = _serving_mix_trace(num_tenants, key_space, events, seed=23)
    n_ops = len(trace)
    s_dt, d_dt, stats = _replay_pair(cfg, tenants, trace, key_space, step_every)
    ratio = d_dt / s_dt
    emit("serve/decode+prefill/server", s_dt / n_ops,
         f"{n_ops / s_dt:.0f}ops/s {stats.ops_per_device_step:.1f}ops/step "
         f"flushes={stats.flushes}")
    emit("serve/decode+prefill/direct", d_dt / n_ops,
         f"{n_ops / d_dt:.0f}ops/s 1 device call/op")
    emit("serve/decode+prefill/ratio", 0.0,
         f"server {ratio:.2f}x direct (acceptance bar >= 3x)")
    assert ratio >= 3.0, (
        f"coalesced server only {ratio:.2f}x call-at-a-time on the "
        f"decode+prefill mix (bar: 3x)")
