"""Paper Table 3: lookup rates (none-exist / all-exist) — LSM vs SA vs cuckoo.

Protocol: for fixed n and batch size b, build every possible LSM with
r = 1..n/b resident batches (we sample r over the range to bound CPU time),
issue n queries, report min/max/harmonic-mean M queries/s. All three
structures run through the unified `Dictionary` facade.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hmean, time_fn


def run(log_n: int = 18, log_bs=(14, 16), r_samples: int = 6) -> None:
    from repro.api import Dictionary

    n = 1 << log_n
    rng = np.random.default_rng(1)
    keys = rng.choice(1 << 29, 2 * n, replace=False).astype(np.int32)
    present, absent = keys[:n], keys[n:]
    vals = (present % 1009).astype(np.int32)

    for log_b in log_bs:
        b = 1 << log_b
        num_batches = n // b
        d = Dictionary.create("lsm", batch_size=b, capacity=n, validate=False)

        rates = {"none": [], "all": []}
        sample_rs = set(np.linspace(1, num_batches, min(r_samples, num_batches), dtype=int))
        for r in range(1, num_batches + 1):
            d = d.insert(jnp.asarray(present[(r - 1) * b : r * b]),
                         jnp.asarray(vals[(r - 1) * b : r * b]))
            if r not in sample_rs:
                continue
            q_all = jnp.asarray(present[rng.integers(0, r * b, n)])
            q_none = jnp.asarray(absent[:n])
            t = time_fn(d.lookup, q_none, warmup=1, iters=3)
            rates["none"].append(n / t / 1e6)
            t = time_fn(d.lookup, q_all, warmup=1, iters=3)
            rates["all"].append(n / t / 1e6)
        for kind in ("none", "all"):
            rs = rates[kind]
            emit(f"table3/lookup_{kind}_b2^{log_b}", 1.0 / (hmean(rs) * 1e6) if rs else 0,
                 f"mean={hmean(rs):.1f}Mq/s min={min(rs):.1f} max={max(rs):.1f}")
        # Fused read path (kernels/lsm_lookup.fused_lookup_runs): on the
        # Pallas backend ONE streaming launch replaces the per-run resolution
        # loop (one lower_bound launch per run + gather/validate). XLA wall
        # time above is unchanged by design — the win is launch count and
        # HBM re-reads on TPU; record the static reduction here.
        num_runs = len(d.state.key_vars) + 1  # levels + write buffer
        emit(f"table3/fused_launch_reduction_b2^{log_b}", 0.0,
             f"runs_probed={num_runs}->1 launch (pallas path)")

    # SA baseline
    sa = Dictionary.create("sorted_array", capacity=n, validate=False)
    sa = sa.bulk_build(jnp.asarray(present), jnp.asarray(vals))
    t = time_fn(sa.lookup, jnp.asarray(absent[:n]), warmup=1, iters=3)
    emit("table3/sa_lookup_none", t / n, f"{n / t / 1e6:.1f}Mq/s")
    t = time_fn(sa.lookup, jnp.asarray(present), warmup=1, iters=3)
    emit("table3/sa_lookup_all", t / n, f"{n / t / 1e6:.1f}Mq/s")

    # cuckoo baseline (80% load)
    ck = Dictionary.create("cuckoo", capacity=n, load_factor=0.8, max_rounds=100,
                           validate=False)
    ck = ck.bulk_build(jnp.asarray(present), jnp.asarray(vals))
    t = time_fn(ck.lookup, jnp.asarray(absent[:n]), warmup=1, iters=3)
    emit("table3/cuckoo_lookup_none", t / n, f"{n / t / 1e6:.1f}Mq/s")
    t = time_fn(ck.lookup, jnp.asarray(present), warmup=1, iters=3)
    emit("table3/cuckoo_lookup_all", t / n, f"{n / t / 1e6:.1f}Mq/s")


if __name__ == "__main__":
    run()
