"""Kernel-level microbenchmarks: XLA reference path vs Pallas (interpret-mode
numbers are NOT wall-time-meaningful on CPU — this bench times the XLA path
and reports the Pallas kernels' roofline-derived expectations for v5e)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ref

HBM_BW = 819e9  # v5e bytes/s


def run(log_n: int = 20) -> None:
    n = 1 << log_n
    rng = np.random.default_rng(5)
    a = jnp.asarray(np.sort(rng.integers(0, 1 << 29, n)).astype(np.int32))
    b = jnp.asarray(np.sort(rng.integers(0, 1 << 29, n)).astype(np.int32))
    va = jnp.arange(n, dtype=jnp.int32)

    merge = jax.jit(ref.merge_ref)
    t = time_fn(merge, a, va, b, va, warmup=1, iters=3)
    emit("kernel/merge_xla", t, f"{2 * n / t / 1e6:.1f}Melem/s")
    # v5e expectation: Merge-Path kernel is stream-bound: 2n*(2 arrays*4B)*(r+w)
    bytes_moved = 2 * n * 4 * 2 * 2
    emit("kernel/merge_v5e_roofline", bytes_moved / HBM_BW,
         f"{2 * n / (bytes_moved / HBM_BW) / 1e6:.0f}Melem/s_bound")

    sort = jax.jit(ref.sort_ref)
    kv = jnp.asarray(rng.integers(0, 1 << 29, n).astype(np.int32))
    t = time_fn(sort, kv, va, warmup=1, iters=3)
    emit("kernel/sort_xla", t, f"{n / t / 1e6:.1f}Melem/s")

    q = jnp.asarray(rng.integers(0, 1 << 29, 1 << 16).astype(np.int32))
    lb = jax.jit(ref.lower_bound_ref)
    t = time_fn(lb, a, q, warmup=1, iters=3)
    emit("kernel/lower_bound_xla", t, f"{q.shape[0] / t / 1e6:.1f}Mq/s")


if __name__ == "__main__":
    run()
