"""Kernel-level microbenchmarks: XLA reference path vs Pallas (interpret-mode
numbers are NOT wall-time-meaningful on CPU — this bench times the XLA path
and reports the Pallas kernels' roofline-derived expectations for v5e).

The fused-lookup sweep (chunk size × DMA buffer depth) times the kernel in
interpret mode: absolute numbers are CPU-interpreter proxies, but the
*relative* ordering tracks launch/chunk bookkeeping overhead, and the v5e
roofline rows give the real-hardware expectation per configuration. The
sweep winner is what `lsm_lookup.FUSED_CHUNK` / `FUSED_DEPTH` encode; a row
flags any drift between the recorded winner and the shipped defaults."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import lsm_lookup, ref

HBM_BW = 819e9  # v5e bytes/s

# Sweep grid for the fused multi-run lookup kernel.
SWEEP_CHUNKS = (512, 1024, 2048)
SWEEP_DEPTHS = (1, 2, 4)


def _fused_sweep(rng, flat_n: int = 1 << 14, nq: int = 512) -> None:
    """chunk × depth sweep of `fused_lookup_runs` (interpret mode)."""
    flat_kv = jnp.asarray(
        np.sort(rng.integers(0, 1 << 29, flat_n)).astype(np.int32)
    )
    flat_val = jnp.arange(flat_n, dtype=jnp.int32)
    q = jnp.asarray(rng.integers(0, 1 << 28, nq).astype(np.int32))
    results = {}
    for chunk in SWEEP_CHUNKS:
        for depth in SWEEP_DEPTHS:
            fn = jax.jit(
                lambda fk, fv, qq, c=chunk, d=depth: lsm_lookup.fused_lookup_runs(
                    fk, fv, qq, chunk=c, query_block=256, depth=d, interpret=True
                )
            )
            t = time_fn(fn, flat_kv, flat_val, q, warmup=1, iters=3)
            results[(chunk, depth)] = t
            # v5e roofline: one full stream of the [2, n] int32 operand per
            # query block, overlapped across `depth` in-flight DMAs.
            bytes_moved = (nq / 256) * 2 * flat_n * 4
            emit(
                f"kernel/fused_lookup_c{chunk}_d{depth}", t,
                f"interpret-proxy; v5e_bound={nq / (bytes_moved / HBM_BW) / 1e6:.0f}Mq/s",
            )
    win_chunk, win_depth = min(results, key=results.get)
    default = (lsm_lookup.FUSED_CHUNK, lsm_lookup.FUSED_DEPTH)
    emit(
        "kernel/fused_lookup_winner", results[(win_chunk, win_depth)],
        f"chunk={win_chunk} depth={win_depth} "
        f"defaults=c{default[0]}_d{default[1]} "
        f"{'MATCH' if (win_chunk, win_depth) == default else 'DRIFT'}",
    )


def run(log_n: int = 20) -> None:
    n = 1 << log_n
    rng = np.random.default_rng(5)
    a = jnp.asarray(np.sort(rng.integers(0, 1 << 29, n)).astype(np.int32))
    b = jnp.asarray(np.sort(rng.integers(0, 1 << 29, n)).astype(np.int32))
    va = jnp.arange(n, dtype=jnp.int32)

    merge = jax.jit(ref.merge_ref)
    t = time_fn(merge, a, va, b, va, warmup=1, iters=3)
    emit("kernel/merge_xla", t, f"{2 * n / t / 1e6:.1f}Melem/s")
    # v5e expectation: Merge-Path kernel is stream-bound: 2n*(2 arrays*4B)*(r+w)
    bytes_moved = 2 * n * 4 * 2 * 2
    emit("kernel/merge_v5e_roofline", bytes_moved / HBM_BW,
         f"{2 * n / (bytes_moved / HBM_BW) / 1e6:.0f}Melem/s_bound")

    sort = jax.jit(ref.sort_ref)
    kv = jnp.asarray(rng.integers(0, 1 << 29, n).astype(np.int32))
    t = time_fn(sort, kv, va, warmup=1, iters=3)
    emit("kernel/sort_xla", t, f"{n / t / 1e6:.1f}Melem/s")

    q = jnp.asarray(rng.integers(0, 1 << 29, 1 << 16).astype(np.int32))
    lb = jax.jit(ref.lower_bound_ref)
    t = time_fn(lb, a, q, warmup=1, iters=3)
    emit("kernel/lower_bound_xla", t, f"{q.shape[0] / t / 1e6:.1f}Mq/s")

    # K-way cascade merge (XLA fold path) vs the pairwise-chain reference —
    # the launch-count savings the fused merge_cascade kernel banks on TPU.
    k_runs = [(jnp.asarray(np.sort(rng.integers(0, 1 << 29, n // 4)).astype(np.int32)),
               jnp.arange(n // 4, dtype=jnp.int32)) for _ in range(4)]
    casc = jax.jit(lambda *flat: ref.merge_cascade_ref(
        list(flat[:4]), list(flat[4:])))
    t = time_fn(casc, *[kv for kv, _ in k_runs], *[v for _, v in k_runs],
                warmup=1, iters=3)
    emit("kernel/merge_cascade4_xla", t, f"{n / t / 1e6:.1f}Melem/s")

    _fused_sweep(rng, flat_n=min(n, 1 << 14))


if __name__ == "__main__":
    run()
