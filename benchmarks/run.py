"""Benchmark driver: one function per paper table/figure + systems suites.

Prints ``name,us_per_call,derived`` CSV rows (assignment deliverable d) and
writes one machine-readable ``BENCH_<name>.json`` per suite (rows + config)
to ``--out-dir`` so successive PRs have a perf trajectory to diff.
``--quick`` shrinks problem sizes for CI-style runs (the streaming suite's
smoke mode).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller problem sizes")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json files")
    args = ap.parse_args()

    from benchmarks import (
        cleanup_bench,
        common,
        fig2_effective_rate,
        kernel_bench,
        serve_bench,
        sharded_bench,
        streaming_bench,
        table2_insertion,
        table3_lookup,
        table4_count_range,
    )

    benches = {
        "table2": lambda: table2_insertion.run(log_n=16 if args.quick else 20,
                                               log_bs=(12, 13) if args.quick else (12, 14, 16)),
        "table3": lambda: table3_lookup.run(log_n=14 if args.quick else 18,
                                            log_bs=(11, 12) if args.quick else (14, 16)),
        "table4": lambda: table4_count_range.run(log_n=13 if args.quick else 16,
                                                 log_bs=(10, 11) if args.quick else (12, 14),
                                                 nq=512 if args.quick else 4096),
        "fig2": lambda: fig2_effective_rate.run(log_b=11 if args.quick else 14,
                                                num_batches=16 if args.quick else 48),
        "cleanup": lambda: cleanup_bench.run(log_n=14 if args.quick else 18,
                                             log_b=11 if args.quick else 14),
        "kernels": lambda: kernel_bench.run(log_n=16 if args.quick else 20),
        "sharded": lambda: sharded_bench.run(log_b=10 if args.quick else 11,
                                             num_batches=8 if args.quick else 16,
                                             nq=512 if args.quick else 2048),
        "streaming": lambda: streaming_bench.run(smoke=args.quick),
        "serve": lambda: serve_bench.run(smoke=args.quick),
    }
    selected = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        common.begin_suite(name, quick=args.quick)
        try:
            benches[name]()
        except BaseException:
            common.abort_suite()  # don't leak the recorder into later suites
            raise
        path = common.end_suite(args.out_dir)
        print(f"# {name} done in {time.time() - t0:.1f}s -> {path}", flush=True)


if __name__ == "__main__":
    main()
