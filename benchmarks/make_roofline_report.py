"""Render results/ROOFLINE.md: baseline vs optimized roofline tables + summary.

  PYTHONPATH=src python -m benchmarks.make_roofline_report
"""

from __future__ import annotations

import glob
import json
import os

HW_NOTE = (
    "TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI. "
    "Terms are seconds/step/chip from the exact-loop-accounting dry-run "
    "(see EXPERIMENTS.md §Roofline for method + the bytes-accessed caveat)."
)

MOVE_NOTES = {
    "compute": "reduce recompute (remat policy) / padding waste",
    "memory": "fuse or shrink activation traffic: bf16 score chains, remat policy, smaller logits dtype",
    "collective": "sharding: ZeRO-3 regather, sharded loss, seq-sharded attention",
}


def load(dir_):
    recs = {}
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs[(r["arch"], r["shape"])] = r
    return recs


def main():
    base = load("results/roofline_base")
    opt = load("results/roofline_opt")
    lines = ["# Roofline — single pod (16x16 = 256 chips)", "", HW_NOTE, ""]

    lines += ["## Baseline (paper-faithful distribution) vs optimized recipe", ""]
    hdr = ("arch", "shape", "base: comp/mem/coll (s)", "base frac", "base dom",
           "opt: comp/mem/coll (s)", "opt frac", "gain", "bottleneck note")
    lines.append("| " + " | ".join(hdr) + " |")
    lines.append("|" + "---|" * len(hdr))
    gains = []
    for key in sorted(base):
        b = base[key]["roofline"]
        o = opt.get(key, {}).get("roofline")
        bcell = f"{b['compute_s']:.2f}/{b['memory_s']:.2f}/{b['collective_s']:.2f}"
        brow = [key[0], key[1], bcell, f"{b['roofline_fraction']:.4f}", b["dominant"]]
        if o:
            ocell = f"{o['compute_s']:.2f}/{o['memory_s']:.2f}/{o['collective_s']:.2f}"
            gain = o["roofline_fraction"] / max(b["roofline_fraction"], 1e-9)
            gains.append(gain)
            brow += [ocell, f"{o['roofline_fraction']:.4f}", f"{gain:.1f}x",
                     MOVE_NOTES[o["dominant"]]]
        else:
            brow += ["-", "-", "-", MOVE_NOTES[b["dominant"]]]
        lines.append("| " + " | ".join(brow) + " |")

    if gains:
        import statistics

        lines += ["",
                  f"**Summary**: optimized recipe improves the roofline fraction on "
                  f"{sum(g > 1.05 for g in gains)}/{len(gains)} cells; median gain "
                  f"{statistics.median(gains):.1f}x, max {max(gains):.1f}x.", ""]
    os.makedirs("results", exist_ok=True)
    with open("results/ROOFLINE.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print("\n".join(lines[:40]))
    print(f"... written to results/ROOFLINE.md ({len(lines)} lines)")


if __name__ == "__main__":
    main()
