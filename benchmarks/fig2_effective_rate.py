"""Paper Fig. 2: (a) per-batch insertion time vs resident batches r (the
binary-counter sawtooth), (b) effective insertion rate (total elements /
cumulative time) for LSM vs SA — the O(1/log n) vs O(1/n) separation."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import LSMConfig, lsm_init, lsm_update
from repro.core.sorted_array import SAConfig, sa_init, sa_update_batch


def run(log_b: int = 14, num_batches: int = 48) -> None:
    b = 1 << log_b
    num_levels = int(np.ceil(np.log2(num_batches + 1)))
    cfg = LSMConfig(batch_size=b, num_levels=num_levels)
    sa_cfg = SAConfig(capacity=b * num_batches)
    rng = np.random.default_rng(3)

    upd = jax.jit(functools.partial(lsm_update, cfg), donate_argnums=0)
    sa_upd = jax.jit(functools.partial(sa_update_batch, sa_cfg), donate_argnums=0)

    # Warm jit caches with throwaway donated states.
    warm_kv = jnp.asarray((rng.integers(0, 1 << 29, b, dtype=np.int32) << 1) | 1)
    warm_val = jnp.zeros(b, jnp.int32)
    jax.block_until_ready(upd(lsm_init(cfg), warm_kv, warm_val))
    jax.block_until_ready(sa_upd(sa_init(sa_cfg), warm_kv, warm_val))

    state, sa_state = lsm_init(cfg), sa_init(sa_cfg)
    t_lsm = t_sa = 0.0
    t_batch = {}
    for r in range(1, num_batches + 1):
        keys = rng.integers(0, 1 << 29, b, dtype=np.int32)
        kv = jnp.asarray((keys << 1) | 1)
        vals = jnp.asarray(keys % 997, jnp.int32)
        # warm the (r-specific) cascade path once via AOT compile of same shapes
        t0 = time.perf_counter()
        state = jax.block_until_ready(upd(state, kv, vals))
        dt = time.perf_counter() - t0
        t_lsm += dt
        t_batch[r] = dt
        t0 = time.perf_counter()
        sa_state = jax.block_until_ready(sa_upd(sa_state, kv, vals))
        t_sa += time.perf_counter() - t0
        if r in (1, 2, 4, 8, 16, 32, num_batches):
            emit(f"fig2a/batch_time_r{r}", t_batch[r],
                 f"ffz={(~r & (r + 1)).bit_length()}levels")
            emit(f"fig2b/effective_r{r}", 0.0,
                 f"lsm={r * b / t_lsm / 1e6:.1f}Melem/s sa={r * b / t_sa / 1e6:.1f}Melem/s")
    emit("fig2b/final_speedup", 0.0, f"{t_sa / t_lsm:.2f}x (grows with n; paper fig2b)")


if __name__ == "__main__":
    run()
