"""Benchmark utilities: wall-clock timing of jitted callables + CSV emission.

Output convention (assignment): ``name,us_per_call,derived`` where `derived`
is the paper's headline unit for that table (M elements/s or M queries/s).

Scaling note: the paper's Tesla K40c tables use n=2^27 elements; this CPU
container runs the same experiment *protocols* at reduced n (scales recorded
in each table's output) — the comparisons (LSM vs SA vs cuckoo ratios) are the
reproduction target, not the absolute K40c numbers. EXPERIMENTS.md §Paper
discusses the mapping.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def bench_dict_updates(d, key_batches, val_batches):
    """Per-batch insert rates through the `Dictionary` facade.

    Mutators consume their input handle (buffer donation), so each batch is
    timed exactly once against the evolving dictionary — the paper's Table 2
    protocol (rate as a function of resident batches r). Returns
    (final_dictionary, rates_in_M_elements_per_s).
    """
    rates = []
    for keys, vals in zip(key_batches, val_batches):
        t0 = time.perf_counter()
        d = d.insert(keys, vals)
        jax.block_until_ready(d.state)
        rates.append(keys.shape[0] / (time.perf_counter() - t0) / 1e6)
    return d, rates


def time_fn(fn, *args, warmup=2, iters=5, **kwargs):
    """Median wall-time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def hmean(xs):
    xs = [x for x in xs if x > 0]
    return len(xs) / sum(1.0 / x for x in xs) if xs else 0.0
