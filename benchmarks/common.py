"""Benchmark utilities: wall-clock timing of jitted callables + CSV emission.

Output convention (assignment): ``name,us_per_call,derived`` where `derived`
is the paper's headline unit for that table (M elements/s or M queries/s).

Scaling note: the paper's Tesla K40c tables use n=2^27 elements; this CPU
container runs the same experiment *protocols* at reduced n (scales recorded
in each table's output) — the comparisons (LSM vs SA vs cuckoo ratios) are the
reproduction target, not the absolute K40c numbers. EXPERIMENTS.md §Paper
discusses the mapping.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup=2, iters=5, **kwargs):
    """Median wall-time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def hmean(xs):
    xs = [x for x in xs if x > 0]
    return len(xs) / sum(1.0 / x for x in xs) if xs else 0.0
