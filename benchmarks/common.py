"""Benchmark utilities: wall-clock timing of jitted callables + CSV/JSON
emission.

Output convention (assignment): ``name,us_per_call,derived`` CSV rows where
`derived` is the paper's headline unit for that table (M elements/s or M
queries/s). In addition, every `emit` inside a `begin_suite`/`end_suite`
window is recorded and written as machine-readable ``BENCH_<suite>.json``
(rows + config + schema version) so successive PRs have a perf trajectory
to diff instead of scraping stdout.

Scaling note: the paper's Tesla K40c tables use n=2^27 elements; this CPU
container runs the same experiment *protocols* at reduced n (scales recorded
in each table's output) — the comparisons (LSM vs SA vs cuckoo ratios) are the
reproduction target, not the absolute K40c numbers. EXPERIMENTS.md §Paper
discusses the mapping.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

# Active JSON recorder (one suite at a time; run.py drives the lifecycle).
_RECORD = {"suite": None, "config": {}, "rows": []}


def begin_suite(name: str, **config) -> None:
    """Start recording emit() rows for BENCH_<name>.json."""
    _RECORD["suite"] = name
    _RECORD["config"] = dict(config)
    _RECORD["rows"] = []


def end_suite(out_dir: str = ".") -> str:
    """Write BENCH_<suite>.json and stop recording. Returns the path."""
    if _RECORD["suite"] is None:
        raise RuntimeError("end_suite() without begin_suite()")
    payload = {
        "schema": 1,
        "suite": _RECORD["suite"],
        "backend": jax.default_backend(),
        "config": _RECORD["config"],
        "rows": _RECORD["rows"],
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{_RECORD['suite']}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    _RECORD["suite"] = None
    _RECORD["config"] = {}
    _RECORD["rows"] = []
    return path


def abort_suite() -> None:
    """Discard the active recording (a bench raised) without writing JSON."""
    _RECORD["suite"] = None
    _RECORD["config"] = {}
    _RECORD["rows"] = []


def bench_dict_updates(d, key_batches, val_batches):
    """Per-batch insert rates through the `Dictionary` facade.

    Mutators consume their input handle (buffer donation), so each batch is
    timed exactly once against the evolving dictionary — the paper's Table 2
    protocol (rate as a function of resident batches r). Returns
    (final_dictionary, rates_in_M_elements_per_s).
    """
    rates = []
    for keys, vals in zip(key_batches, val_batches):
        t0 = time.perf_counter()
        d = d.insert(keys, vals)
        jax.block_until_ready(d.state)
        rates.append(keys.shape[0] / (time.perf_counter() - t0) / 1e6)
    return d, rates


def time_fn(fn, *args, warmup=2, iters=5, **kwargs):
    """Median wall-time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    if _RECORD["suite"] is not None:
        _RECORD["rows"].append(
            {"name": name, "us_per_call": round(seconds * 1e6, 3), "derived": derived}
        )


def hmean(xs):
    xs = [x for x in xs if x > 0]
    return len(xs) / sum(1.0 / x for x in xs) if xs else 0.0
