"""Paper §5.4: cleanup throughput vs removal fraction, cleanup vs rebuild,
the query-speedup-after-cleanup experiment — plus the sustained-churn
latency comparison of stop-the-world `cleanup()` against budgeted
`maintain()` (ISSUE 7: p50 should match, p99 should collapse because the
maintenance slice is bounded while the periodic cleanup is O(capacity))."""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import (
    LSMConfig,
    lsm_bulk_build,
    lsm_cleanup,
    lsm_delete,
    lsm_init,
    lsm_insert,
    lsm_lookup,
    lsm_maintain,
)


def _build_with_deletes(cfg, n, frac_deleted, rng):
    b = cfg.batch_size
    keys = rng.choice(1 << 29, n, replace=False).astype(np.int32)
    state = lsm_init(cfg)
    ins = jax.jit(functools.partial(lsm_insert, cfg), donate_argnums=0)
    dele = jax.jit(functools.partial(lsm_delete, cfg), donate_argnums=0)
    for r in range(n // b):
        state = ins(state, jnp.asarray(keys[r * b : (r + 1) * b]),
                    jnp.asarray(keys[r * b : (r + 1) * b] % 997))
    n_del = int(n * frac_deleted)
    for r in range(max(1, n_del // b)):
        state = dele(state, jnp.asarray(keys[r * b : (r + 1) * b]))
    return state, keys


def run(log_n: int = 18, log_b: int = 14) -> None:
    n, b = 1 << log_n, 1 << log_b
    num_levels = int(np.ceil(np.log2(n // b + 1))) + 1
    cfg = LSMConfig(batch_size=b, num_levels=num_levels)
    rng = np.random.default_rng(4)
    clean = jax.jit(functools.partial(lsm_cleanup, cfg))

    for frac in (0.1, 0.5):
        state, keys = _build_with_deletes(cfg, n, frac, rng)
        resident = int(state.r) * b
        t = time_fn(clean, state, warmup=1, iters=3)
        emit(f"cleanup/frac{int(frac * 100)}", t,
             f"{resident / t / 1e6:.1f}Melem/s (paper: ~1800 M/s @K40c)")

    # cleanup vs from-scratch rebuild (sort of all resident elements)
    state, keys = _build_with_deletes(cfg, n, 0.1, rng)
    bb = jax.jit(functools.partial(lsm_bulk_build, cfg))
    t_re = time_fn(bb, jnp.asarray(keys), jnp.zeros(n, jnp.int32), warmup=1, iters=3)
    t_cl = time_fn(clean, state, warmup=1, iters=3)
    emit("cleanup/vs_rebuild", t_cl, f"speedup={t_re / t_cl:.2f}x (paper: up to 2.5x)")

    # queries after cleanup (paper: 4.8x incl. cleanup time at r=2^7-1)
    look = jax.jit(functools.partial(lsm_lookup, cfg))
    q = jnp.asarray(rng.choice(keys, n // 4))
    t_before = time_fn(look, state, q, warmup=1, iters=3)
    cleaned = clean(state)
    t_after = time_fn(look, cleaned, q, warmup=1, iters=3)
    emit("cleanup/query_speedup", t_after,
         f"lookup_before={t_before * 1e3:.1f}ms after={t_after * 1e3:.1f}ms "
         f"speedup={t_before / t_after:.2f}x")

    _churn(log_b=min(log_b, 11))


def _churn(log_b: int = 11, steps: int = 32, cleanup_every: int = 8) -> None:
    """Sustained update churn: per-step latency under two compaction regimes.

    Each step applies one full insert batch from a small key space (heavy
    cross-batch shadowing) followed by the regime's compaction work:

      * 'cleanup'  — stop-the-world `lsm_cleanup` every `cleanup_every`
        steps (the paper's only option): most steps are cheap, but the
        cleanup step rebuilds O(capacity) elements -> a p99 spike;
      * 'maintain' — `lsm_maintain(3b)` every step: bounded incremental
        slices keep every step's cost flat.

    Both regimes see the SAME key sequence; queries stay exact throughout
    (the differential harness owns that proof — this bench only times it).
    """
    b = 1 << log_b
    num_levels = 5  # capacity 31 * b
    cfg = LSMConfig(batch_size=b, num_levels=num_levels)
    key_space = 4 * b  # ~every key rewritten every 4 batches
    rng = np.random.default_rng(11)
    batches = [rng.choice(key_space, b, replace=False).astype(np.int32)
               for _ in range(steps)]

    ins = jax.jit(functools.partial(lsm_insert, cfg), donate_argnums=0)
    clean = jax.jit(functools.partial(lsm_cleanup, cfg), donate_argnums=0)
    maint = jax.jit(functools.partial(lsm_maintain, cfg, budget=3 * b),
                    donate_argnums=0)

    def run_regime(compact_step):
        # Two full replays: the first warms every executable involved so
        # compile time stays out of the latency distribution; the second's
        # per-step timings are what we report.
        for trial in range(2):
            state = lsm_init(cfg)
            lat = []
            for i, keys in enumerate(batches):
                t0 = time.perf_counter()
                state = ins(state, jnp.asarray(keys), jnp.asarray(keys % 997))
                state = compact_step(state, i)
                jax.block_until_ready(state)
                lat.append(time.perf_counter() - t0)
        return np.array(lat)

    lat_cl = run_regime(
        lambda st, i: clean(st) if (i + 1) % cleanup_every == 0 else st
    )
    lat_mt = run_regime(lambda st, i: maint(st))

    for tag, lat in (("cleanup", lat_cl), ("maintain", lat_mt)):
        p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
        emit(f"churn/{tag}_p50", float(p50), f"{b / p50 / 1e6:.1f}Melem/s")
        emit(f"churn/{tag}_p99", float(p99),
             f"spread={p99 / p50:.1f}x (flat p99 = bounded maintenance)")
    emit("churn/p99_ratio", float(np.percentile(lat_cl, 99)),
         f"cleanup_p99/maintain_p99="
         f"{np.percentile(lat_cl, 99) / np.percentile(lat_mt, 99):.2f}x")


if __name__ == "__main__":
    run()
