"""Paper §5.4: cleanup throughput vs removal fraction, cleanup vs rebuild,
and the query-speedup-after-cleanup experiment."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import (
    LSMConfig,
    lsm_bulk_build,
    lsm_cleanup,
    lsm_delete,
    lsm_init,
    lsm_insert,
    lsm_lookup,
)


def _build_with_deletes(cfg, n, frac_deleted, rng):
    b = cfg.batch_size
    keys = rng.choice(1 << 29, n, replace=False).astype(np.int32)
    state = lsm_init(cfg)
    ins = jax.jit(functools.partial(lsm_insert, cfg), donate_argnums=0)
    dele = jax.jit(functools.partial(lsm_delete, cfg), donate_argnums=0)
    for r in range(n // b):
        state = ins(state, jnp.asarray(keys[r * b : (r + 1) * b]),
                    jnp.asarray(keys[r * b : (r + 1) * b] % 997))
    n_del = int(n * frac_deleted)
    for r in range(max(1, n_del // b)):
        state = dele(state, jnp.asarray(keys[r * b : (r + 1) * b]))
    return state, keys


def run(log_n: int = 18, log_b: int = 14) -> None:
    n, b = 1 << log_n, 1 << log_b
    num_levels = int(np.ceil(np.log2(n // b + 1))) + 1
    cfg = LSMConfig(batch_size=b, num_levels=num_levels)
    rng = np.random.default_rng(4)
    clean = jax.jit(functools.partial(lsm_cleanup, cfg))

    for frac in (0.1, 0.5):
        state, keys = _build_with_deletes(cfg, n, frac, rng)
        resident = int(state.r) * b
        t = time_fn(clean, state, warmup=1, iters=3)
        emit(f"cleanup/frac{int(frac * 100)}", t,
             f"{resident / t / 1e6:.1f}Melem/s (paper: ~1800 M/s @K40c)")

    # cleanup vs from-scratch rebuild (sort of all resident elements)
    state, keys = _build_with_deletes(cfg, n, 0.1, rng)
    bb = jax.jit(functools.partial(lsm_bulk_build, cfg))
    t_re = time_fn(bb, jnp.asarray(keys), jnp.zeros(n, jnp.int32), warmup=1, iters=3)
    t_cl = time_fn(clean, state, warmup=1, iters=3)
    emit("cleanup/vs_rebuild", t_cl, f"speedup={t_re / t_cl:.2f}x (paper: up to 2.5x)")

    # queries after cleanup (paper: 4.8x incl. cleanup time at r=2^7-1)
    look = jax.jit(functools.partial(lsm_lookup, cfg))
    q = jnp.asarray(rng.choice(keys, n // 4))
    t_before = time_fn(look, state, q, warmup=1, iters=3)
    cleaned = clean(state)
    t_after = time_fn(look, cleaned, q, warmup=1, iters=3)
    emit("cleanup/query_speedup", t_after,
         f"lookup_before={t_before * 1e3:.1f}ms after={t_after * 1e3:.1f}ms "
         f"speedup={t_before / t_after:.2f}x")


if __name__ == "__main__":
    run()
