"""Paper Table 2: batch insertion rates — GPU LSM vs sorted array, + cuckoo
bulk-build rate. Protocol: insert n/b batches incrementally; record the
per-batch rate for every resident-batch count r; report min/max/harmonic mean.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hmean, time_fn
from repro.core import LSMConfig, lsm_init, lsm_update
from repro.core import semantics as sem
from repro.core.cuckoo import CuckooConfig, cuckoo_build
from repro.core.sorted_array import SAConfig, sa_init, sa_update_batch
from repro.kernels import ops


def run(log_n: int = 20, log_bs=(12, 14, 16)) -> None:
    n = 1 << log_n
    rng = np.random.default_rng(0)
    rows = []
    for log_b in log_bs:
        b = 1 << log_b
        num_batches = n // b
        num_levels = max(1, int(np.ceil(np.log2(num_batches + 1))))
        cfg = LSMConfig(batch_size=b, num_levels=num_levels)
        upd = jax.jit(functools.partial(lsm_update, cfg), donate_argnums=0)

        sa_cfg = SAConfig(capacity=n)
        sa_upd = jax.jit(functools.partial(sa_update_batch, sa_cfg), donate_argnums=0)

        # Warm both jit caches with throwaway donated states.
        warm_kv = jnp.asarray((rng.integers(0, sem.MAX_USER_KEY, b, dtype=np.int32) << 1) | 1)
        warm_val = jnp.zeros(b, jnp.int32)
        jax.block_until_ready(upd(lsm_init(cfg), warm_kv, warm_val))
        jax.block_until_ready(sa_upd(sa_init(sa_cfg), warm_kv, warm_val))

        lsm_rates, sa_rates = [], []
        state = lsm_init(cfg)
        sa_state = sa_init(sa_cfg)
        import time as _time

        for r in range(num_batches):
            keys = rng.integers(0, sem.MAX_USER_KEY, b, dtype=np.int32)
            kv = jnp.asarray((keys.astype(np.int64) << 1 | 1).astype(np.int32))
            vals = jnp.asarray(keys % 1009, jnp.int32)
            t0 = _time.perf_counter()
            state = jax.block_until_ready(upd(state, kv, vals))
            lsm_rates.append(b / (_time.perf_counter() - t0) / 1e6)
            t0 = _time.perf_counter()
            sa_state = jax.block_until_ready(sa_upd(sa_state, kv, vals))
            sa_rates.append(b / (_time.perf_counter() - t0) / 1e6)
        name = f"table2/insert_b2^{log_b}_n2^{log_n}"
        emit(f"{name}/lsm", b / (hmean(lsm_rates) * 1e6) if lsm_rates else 0,
             f"lsm_mean={hmean(lsm_rates):.1f}Melem/s min={min(lsm_rates):.1f} max={max(lsm_rates):.1f}")
        emit(f"{name}/sa", b / (hmean(sa_rates) * 1e6) if sa_rates else 0,
             f"sa_mean={hmean(sa_rates):.1f}Melem/s min={min(sa_rates):.1f} max={max(sa_rates):.1f}")
        rows.append((b, hmean(lsm_rates), hmean(sa_rates)))

    speedups = [l / s for _, l, s in rows if s > 0]
    emit("table2/lsm_vs_sa_speedup", 0.0,
         f"harmonic-mean-speedup={hmean(speedups):.2f}x (paper: 13.5x @ 2^27)")

    # cuckoo bulk build at 80% load (paper: 361.7 M/s on K40c)
    nk = 1 << (log_n - 2)
    keys = rng.choice(1 << 29, nk, replace=False).astype(np.int32)
    ccfg = CuckooConfig(table_size=int(nk / 0.8), max_rounds=100)
    build = jax.jit(functools.partial(cuckoo_build, ccfg))
    t = time_fn(build, jnp.asarray(keys), jnp.asarray(keys), warmup=1, iters=3)
    emit("table2/cuckoo_build", t, f"{nk / t / 1e6:.1f}Melem/s")

    # LSM bulk build (sort + segment; paper: 727.8 M/s)
    from repro.core import lsm_bulk_build

    cfg = LSMConfig(batch_size=1 << 14, num_levels=int(np.log2(n >> 14)) + 1)
    nb = (n // cfg.batch_size // 2) * cfg.batch_size
    bb = jax.jit(functools.partial(lsm_bulk_build, cfg))
    t = time_fn(bb, jnp.asarray(keys[:nb] if nb <= nk else np.resize(keys, nb)),
                jnp.zeros(nb, jnp.int32), warmup=1, iters=3)
    emit("table2/lsm_bulk_build", t, f"{nb / t / 1e6:.1f}Melem/s")


if __name__ == "__main__":
    run()
