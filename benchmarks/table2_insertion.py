"""Paper Table 2: batch insertion rates — GPU LSM vs sorted array, + cuckoo
bulk-build rate. Protocol: insert n/b batches incrementally; record the
per-batch rate for every resident-batch count r; report min/max/harmonic mean.

Everything runs through the unified `Dictionary` facade — the facade owns the
jit/donation plumbing the hand-rolled version carried per backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dict_updates, emit, hmean, time_fn
from repro.api import Dictionary
from repro.core import semantics as sem


def run(log_n: int = 20, log_bs=(12, 14, 16)) -> None:
    n = 1 << log_n
    rng = np.random.default_rng(0)
    rows = []
    for log_b in log_bs:
        b = 1 << log_b
        num_batches = n // b

        # Warm both executable caches with throwaway dictionaries.
        warm_keys = jnp.asarray(rng.integers(0, sem.MAX_USER_KEY, b, dtype=np.int32))
        warm_vals = jnp.zeros(b, jnp.int32)
        for backend in ("lsm", "sorted_array"):
            w = Dictionary.create(backend, batch_size=b, capacity=n, validate=False)
            jax.block_until_ready(w.insert(warm_keys, warm_vals).state)

        key_batches, val_batches = [], []
        for _ in range(num_batches):
            keys = rng.integers(0, sem.MAX_USER_KEY, b, dtype=np.int32)
            key_batches.append(jnp.asarray(keys))
            val_batches.append(jnp.asarray(keys % 1009, np.int32))

        lsm = Dictionary.create("lsm", batch_size=b, capacity=n, validate=False)
        _, lsm_rates = bench_dict_updates(lsm, key_batches, val_batches)
        sa = Dictionary.create("sorted_array", batch_size=b, capacity=n, validate=False)
        _, sa_rates = bench_dict_updates(sa, key_batches, val_batches)

        name = f"table2/insert_b2^{log_b}_n2^{log_n}"
        emit(f"{name}/lsm", b / (hmean(lsm_rates) * 1e6) if lsm_rates else 0,
             f"lsm_mean={hmean(lsm_rates):.1f}Melem/s min={min(lsm_rates):.1f} max={max(lsm_rates):.1f}")
        emit(f"{name}/sa", b / (hmean(sa_rates) * 1e6) if sa_rates else 0,
             f"sa_mean={hmean(sa_rates):.1f}Melem/s min={min(sa_rates):.1f} max={max(sa_rates):.1f}")
        rows.append((b, hmean(lsm_rates), hmean(sa_rates)))

    speedups = [l / s for _, l, s in rows if s > 0]
    emit("table2/lsm_vs_sa_speedup", 0.0,
         f"harmonic-mean-speedup={hmean(speedups):.2f}x (paper: 13.5x @ 2^27)")

    # cuckoo bulk build at 80% load (paper: 361.7 M/s on K40c)
    nk = 1 << (log_n - 2)
    nb = (n // (1 << 14) // 2) * (1 << 14)  # LSM bulk-build size (below)
    keys = rng.choice(1 << 29, max(nk, nb), replace=False).astype(np.int32)
    ck = Dictionary.create("cuckoo", capacity=nk, load_factor=0.8, max_rounds=100,
                           validate=False)
    t = time_fn(ck.bulk_build, jnp.asarray(keys[:nk]), jnp.asarray(keys[:nk]),
                warmup=1, iters=3)
    emit("table2/cuckoo_build", t, f"{nk / t / 1e6:.1f}Melem/s")

    # LSM bulk build (sort + segment; paper: 727.8 M/s)
    lsm = Dictionary.create("lsm", batch_size=1 << 14, capacity=n, validate=False)
    t = time_fn(lsm.bulk_build, jnp.asarray(keys[:nb]), jnp.zeros(nb, jnp.int32),
                warmup=1, iters=3)
    emit("table2/lsm_bulk_build", t, f"{nb / t / 1e6:.1f}Melem/s")


if __name__ == "__main__":
    run()
