"""lsm vs lsm_sharded through the unified facade: update / lookup / count.

Protocol mirrors Table 2/3 at reduced n: insert `num_batches` b-wide batches
(facade pad/split path, donation included), then time bulk lookups and
full-width counts. On a spoofed-CPU pool the absolute rates mean little —
the deliverable is that the sharded backend runs the *same* benchmark body
as the single-device LSM with zero facade changes, and the relative cost of
the all-gather + psum combines is visible.

Run with a widened pool, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m benchmarks.run --only sharded
Single-device pools fall back to comparing lsm vs lsm_sharded@1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dict_updates, emit, hmean, time_fn
from repro.api import Dictionary, QueryPlan
from repro.core import semantics as sem


def run(log_b: int = 11, num_batches: int = 16, nq: int = 2048) -> None:
    b = 1 << log_b
    n = b * num_batches
    shards = min(4, len(jax.devices()))
    rng = np.random.default_rng(0)

    key_batches = [
        jnp.asarray(rng.integers(0, sem.MAX_USER_KEY, b, dtype=np.int32))
        for _ in range(num_batches)
    ]
    val_batches = [jnp.asarray(np.asarray(k) % 1009, jnp.int32) for k in key_batches]
    queries = jnp.asarray(rng.integers(0, sem.MAX_USER_KEY, nq, dtype=np.int32))
    k1 = jnp.zeros((64,), jnp.int32)
    k2 = jnp.full((64,), sem.MAX_USER_KEY, jnp.int32)
    plan = QueryPlan(max_candidates=4096, max_results=64)

    def backends():
        yield "lsm", {}
        yield f"lsm_sharded@{shards}", {"num_shards": shards}

    for name, extra in backends():
        backend = "lsm_sharded" if "@" in name else name
        # warm the executable cache off the clock
        w = Dictionary.create(backend, batch_size=b, capacity=n, validate=False, **extra)
        jax.block_until_ready(w.insert(key_batches[0], val_batches[0]).state)

        d = Dictionary.create(backend, batch_size=b, capacity=n, validate=False, **extra)
        d, rates = bench_dict_updates(d, key_batches, val_batches)
        emit(f"sharded/{name}/insert", b / (hmean(rates) * 1e6) if rates else 0,
             f"mean={hmean(rates):.1f}Melem/s")

        t = time_fn(d.lookup, queries)
        emit(f"sharded/{name}/lookup", t, f"{nq / t / 1e6:.1f}Mq/s")

        t = time_fn(d.count, k1, k2, plan)
        emit(f"sharded/{name}/count", t, f"{64 / t / 1e3:.1f}Kq/s")


if __name__ == "__main__":
    run()
