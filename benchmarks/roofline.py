"""Aggregate dry-run JSONs into the §Roofline table (markdown + CSV).

  PYTHONPATH=src python -m benchmarks.roofline --dir results/dryrun [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str, mesh: str | None = None):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_table(recs, md=True):
    rows = []
    header = ("arch", "shape", "mesh", "compute_ms", "memory_ms", "coll_ms",
              "dominant", "useful_flop_ratio", "roofline_frac")
    for r in recs:
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], r.get("mesh", "?"), "-", "-", "-",
                         "ERROR", "-", "-"))
            continue
        ro = r["roofline"]
        rows.append((
            r["arch"], r["shape"], r["mesh"],
            f"{ro['compute_s']*1e3:.2f}", f"{ro['memory_s']*1e3:.2f}",
            f"{ro['collective_s']*1e3:.2f}", ro["dominant"],
            f"{ro['useful_flop_ratio']:.3f}", f"{ro['roofline_fraction']:.3f}",
        ))
    if md:
        out = ["| " + " | ".join(header) + " |",
               "|" + "---|" * len(header)]
        out += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
    else:
        out = [",".join(header)] + [",".join(str(c) for c in row) for row in rows]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(fmt_table(recs, md=not args.csv))
    ok = [r for r in recs if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"]
                   / max(1e-12, max(r["roofline"]["compute_s"], r["roofline"]["memory_s"])))
        print(f"\n# cells: {len(ok)} ok / {len(recs)} total")
        print(f"# worst roofline fraction: {worst['arch']}/{worst['shape']}/{worst['mesh']} "
              f"= {worst['roofline']['roofline_fraction']:.4f}")
        print(f"# most collective-bound: {coll['arch']}/{coll['shape']}/{coll['mesh']} "
              f"(coll {coll['roofline']['collective_s']*1e3:.1f}ms)")


if __name__ == "__main__":
    main()
