"""Streaming sub-batch update benchmark: the write-buffer ("level −1") path.

Serving workloads trickle in ragged sub-batches, not b-aligned batches. This
suite measures what the staging buffer buys over the old pad-every-call
facade policy:

  1. sub-batch insert *rate* for sizes s << b (each call stages s lanes and
     flushes at most once per b staged elements, vs. one full placebo-padded
     cascade per call before);
  2. live-capacity *consumption*: N size-s updates must consume
     floor(N*s/b) batch slots (ceil after a flush), not N — so the
     capacity-overflow point for size-1 inserts improves ~b×. Both the slot
     count and the measured overflow point are asserted, not just printed.

Emits CSV rows like every other suite and records them for
BENCH_streaming.json (benchmarks/common.py).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dict_updates, emit, hmean
from repro.api import Dictionary
from repro.core import semantics as sem


def run(log_b: int = 10, sub_sizes=(1, 16, 256), n_calls: int = 64,
        smoke: bool = False) -> None:
    if smoke:
        log_b, sub_sizes, n_calls = 6, (1, 8), 24
    b = 1 << log_b
    rng = np.random.default_rng(0)

    # --- 1. sub-batch insert rates -----------------------------------------
    for s in sub_sizes:
        d = Dictionary.create("lsm", batch_size=b, num_levels=8, validate=False)
        keys = [jnp.asarray(rng.integers(0, sem.MAX_USER_KEY, s, dtype=np.int32))
                for _ in range(n_calls)]
        vals = [jnp.asarray(rng.integers(0, 1 << 20, s, dtype=np.int32))
                for _ in range(n_calls)]
        # warm the staged-update executable
        w = Dictionary.create("lsm", batch_size=b, num_levels=8, validate=False)
        jax.block_until_ready(w.insert(keys[0], vals[0]).state)
        d, rates = bench_dict_updates(d, keys, vals)
        name = f"streaming/insert_s{s}_b2^{log_b}"
        emit(name, s / (hmean(rates) * 1e6) if rates else 0,
             f"sub-batch rate={hmean(rates):.2f}Melem/s over {n_calls} calls")
        # staged coalescing: N*s elements may occupy at most ceil(N*s/b) slots
        slots = int(d.state.r)
        max_slots = -(-n_calls * s // b)
        assert slots <= max_slots, (slots, max_slots)
        emit(f"{name}/slots", 0.0,
             f"batch_slots={slots} (<= ceil(N*s/b)={max_slots}; pad-every-call "
             f"policy would use {n_calls})")

    # --- 2. capacity-overflow point for size-1 inserts ---------------------
    # Tiny LSM so the experiment is fast: capacity = bb * (2^L - 1).
    bb, levels = (8, 3) if smoke else (32, 3)
    max_batches = (1 << levels) - 1
    d = Dictionary.create("lsm", batch_size=bb, num_levels=levels, validate=False)
    n_inserts = 0
    t0 = time.perf_counter()
    # The old policy overflowed after max_batches size-1 calls; the buffer
    # sustains ~bb * max_batches + bb before the latch trips.
    limit = bb * (max_batches + 1) + 1
    while not bool(d.overflowed()) and n_inserts < limit:
        d = d.insert(np.array([n_inserts % sem.MAX_USER_KEY]), np.array([1]))
        n_inserts += 1
    dt = time.perf_counter() - t0
    overflow_point = n_inserts
    improvement = overflow_point / max_batches
    assert overflow_point >= bb * max_batches, (overflow_point, bb * max_batches)
    emit(f"streaming/overflow_point_b{bb}_L{levels}", dt / max(n_inserts, 1),
         f"size-1 inserts before overflow={overflow_point} vs pad-every-call "
         f"policy={max_batches} ({improvement:.0f}x, ~b={bb})")

    # --- 3. flush-threshold policy cost ------------------------------------
    s = sub_sizes[0]
    for threshold, label in ((1, "flush_every_call"), (None, "coalesce")):
        d = Dictionary.create("lsm", batch_size=b, num_levels=8, validate=False,
                              flush_threshold=threshold)
        keys = [jnp.asarray(rng.integers(0, sem.MAX_USER_KEY, s, dtype=np.int32))
                for _ in range(n_calls)]
        vals = [jnp.asarray(np.ones(s, np.int32)) for _ in range(n_calls)]
        w = Dictionary.create("lsm", batch_size=b, num_levels=8, validate=False,
                              flush_threshold=threshold)
        jax.block_until_ready(w.insert(keys[0], vals[0]).state)  # warm
        d, rates = bench_dict_updates(d, keys, vals)
        emit(f"streaming/policy_{label}_s{s}", s / (hmean(rates) * 1e6) if rates else 0,
             f"rate={hmean(rates):.2f}Melem/s slots={int(d.state.r)}")
