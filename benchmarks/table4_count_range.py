"""Paper Table 4: COUNT and RANGE query rates at expected range L=8 and
L=1024 — LSM vs SA. Queries are (k1, k1+W) with W chosen so the expected
number of in-range keys is L (keys uniform in [0, KEY_HI))."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, hmean, time_fn
from repro.core import LSMConfig, lsm_count, lsm_init, lsm_insert, lsm_range
from repro.core.sorted_array import SAConfig, sa_bulk_build, sa_count, sa_range

KEY_HI = 1 << 28


def run(log_n: int = 16, log_bs=(12, 14), ls=(8, 1024), nq: int = 4096) -> None:
    n = 1 << log_n
    rng = np.random.default_rng(2)
    keys = rng.choice(KEY_HI, n, replace=False).astype(np.int32)
    vals = (keys % 1009).astype(np.int32)

    for log_b in log_bs:
        b = 1 << log_b
        num_batches = n // b
        num_levels = max(1, int(np.ceil(np.log2(num_batches + 1))))
        cfg = LSMConfig(batch_size=b, num_levels=num_levels)
        state = lsm_init(cfg)
        ins = jax.jit(functools.partial(lsm_insert, cfg), donate_argnums=0)
        for r in range(num_batches):
            state = ins(state, jnp.asarray(keys[r * b : (r + 1) * b]),
                        jnp.asarray(vals[r * b : (r + 1) * b]))
        for L in ls:
            width = int(L * KEY_HI / n)
            k1 = rng.integers(0, KEY_HI - width, nq).astype(np.int32)
            k2 = (k1 + width).astype(np.int32)
            max_cand = max(64, 2 * L)
            cnt = jax.jit(functools.partial(lsm_count, cfg, max_candidates=max_cand))
            t = time_fn(cnt, state, jnp.asarray(k1), jnp.asarray(k2), warmup=1, iters=3)
            emit(f"table4/count_b2^{log_b}_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")
            rngq = jax.jit(functools.partial(lsm_range, cfg, max_candidates=max_cand,
                                             max_results=max_cand))
            t = time_fn(rngq, state, jnp.asarray(k1), jnp.asarray(k2), warmup=1, iters=3)
            emit(f"table4/range_b2^{log_b}_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")

    # SA baseline
    sa_cfg = SAConfig(capacity=n)
    sa = sa_bulk_build(sa_cfg, jnp.asarray(keys), jnp.asarray(vals))
    for L in ls:
        width = int(L * KEY_HI / n)
        k1 = rng.integers(0, KEY_HI - width, nq).astype(np.int32)
        k2 = (k1 + width).astype(np.int32)
        max_cand = max(64, 2 * L)
        c = jax.jit(functools.partial(sa_count, sa_cfg, max_candidates=max_cand))
        t = time_fn(c, sa, jnp.asarray(k1), jnp.asarray(k2), warmup=1, iters=3)
        emit(f"table4/sa_count_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")
        r = jax.jit(functools.partial(sa_range, sa_cfg, max_candidates=max_cand,
                                      max_results=max_cand))
        t = time_fn(r, sa, jnp.asarray(k1), jnp.asarray(k2), warmup=1, iters=3)
        emit(f"table4/sa_range_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")


if __name__ == "__main__":
    run()
