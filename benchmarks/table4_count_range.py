"""Paper Table 4: COUNT and RANGE query rates at expected range L=8 and
L=1024 — LSM vs SA. Queries are (k1, k1+W) with W chosen so the expected
number of in-range keys is L (keys uniform in [0, KEY_HI)). Both structures
run through the unified `Dictionary` facade; the per-L candidate bound is an
explicit `QueryPlan` (the paper's max_candidates knob)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn

KEY_HI = 1 << 28


def run(log_n: int = 16, log_bs=(12, 14), ls=(8, 1024), nq: int = 4096) -> None:
    from repro.api import Dictionary, QueryPlan

    n = 1 << log_n
    rng = np.random.default_rng(2)
    keys = rng.choice(KEY_HI, n, replace=False).astype(np.int32)
    vals = (keys % 1009).astype(np.int32)

    for log_b in log_bs:
        b = 1 << log_b
        num_batches = n // b
        d = Dictionary.create("lsm", batch_size=b, capacity=n, validate=False)
        for r in range(num_batches):
            d = d.insert(jnp.asarray(keys[r * b : (r + 1) * b]),
                         jnp.asarray(vals[r * b : (r + 1) * b]))
        for L in ls:
            width = int(L * KEY_HI / n)
            k1 = rng.integers(0, KEY_HI - width, nq).astype(np.int32)
            k2 = (k1 + width).astype(np.int32)
            plan = QueryPlan(max_candidates=max(64, 2 * L), max_results=max(64, 2 * L))
            t = time_fn(d.count, jnp.asarray(k1), jnp.asarray(k2), plan,
                        warmup=1, iters=3)
            emit(f"table4/count_b2^{log_b}_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")
            t = time_fn(d.range, jnp.asarray(k1), jnp.asarray(k2), plan,
                        warmup=1, iters=3)
            emit(f"table4/range_b2^{log_b}_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")

    # SA baseline
    sa = Dictionary.create("sorted_array", capacity=n, validate=False)
    sa = sa.bulk_build(jnp.asarray(keys), jnp.asarray(vals))
    for L in ls:
        width = int(L * KEY_HI / n)
        k1 = rng.integers(0, KEY_HI - width, nq).astype(np.int32)
        k2 = (k1 + width).astype(np.int32)
        plan = QueryPlan(max_candidates=max(64, 2 * L), max_results=max(64, 2 * L))
        t = time_fn(sa.count, jnp.asarray(k1), jnp.asarray(k2), plan, warmup=1, iters=3)
        emit(f"table4/sa_count_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")
        t = time_fn(sa.range, jnp.asarray(k1), jnp.asarray(k2), plan, warmup=1, iters=3)
        emit(f"table4/sa_range_L{L}", t / nq, f"{nq / t / 1e6:.3f}Mq/s")


if __name__ == "__main__":
    run()
