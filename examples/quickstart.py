"""Quickstart: the GPU-LSM dictionary on TPU/JAX in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import (
    LSMConfig,
    lsm_cleanup,
    lsm_count,
    lsm_delete,
    lsm_init,
    lsm_insert,
    lsm_lookup,
    lsm_range,
    lsm_valid_count,
)


def main():
    # b = 1024-element batches, 10 levels => capacity ~1M elements.
    cfg = LSMConfig(batch_size=1024, num_levels=10)
    state = lsm_init(cfg)

    insert = jax.jit(functools.partial(lsm_insert, cfg), donate_argnums=0)
    delete = jax.jit(functools.partial(lsm_delete, cfg), donate_argnums=0)
    lookup = jax.jit(functools.partial(lsm_lookup, cfg))

    # 1) batch inserts — the only way in (bulk-synchronous, sorted + merged)
    for batch in range(4):
        keys = jnp.arange(1024) + batch * 1024
        state = insert(state, keys, keys * 10)
    print(f"inserted 4 batches; resident batches r={int(state.r)} "
          f"(levels full where bits of r are set: {int(state.r):b})")

    # 2) point lookups — most-recent value wins
    found, vals = lookup(state, jnp.array([0, 1500, 4095, 99999]))
    print("lookup [0, 1500, 4095, 99999]:", found.tolist(), vals.tolist())

    # 3) overwrite: re-insert key 0 with a new value
    state = insert(state, jnp.arange(1024), jnp.full((1024,), 777))
    _, vals = lookup(state, jnp.array([0]))
    print("after overwrite, key 0 ->", int(vals[0]))

    # 4) delete a batch (tombstones)
    state = delete(state, jnp.arange(1024) + 1024)
    found, _ = lookup(state, jnp.array([1500]))
    print("key 1500 after delete:", bool(found[0]))

    # 5) ordered queries (hash tables can't do this)
    counts, ok = lsm_count(cfg, state, jnp.array([0, 2048]), jnp.array([4095, 3000]), 1 << 14)
    print(f"COUNT[0,4095]={int(counts[0])}  COUNT[2048,3000]={int(counts[1])} (exact={bool(ok.all())})")
    keys, vals, cnt, ok = lsm_range(cfg, state, jnp.array([2040]), jnp.array([2050]), 256, 16)
    print("RANGE[2040,2050] ->", keys[0][: int(cnt[0])].tolist())

    # 6) cleanup: purge tombstones + duplicates, shrink levels
    live = int(lsm_valid_count(cfg, state))
    state = lsm_cleanup(cfg, state)
    print(f"cleanup: {live} live elements packed into r={int(state.r)} batches")


if __name__ == "__main__":
    main()
