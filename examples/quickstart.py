"""Quickstart: the GPU-LSM dictionary on TPU/JAX in 60 seconds.

One `Dictionary` facade covers all three of the paper's data structures —
no jax.jit / functools.partial / donation plumbing anywhere: the facade
compiles and caches every op internally.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import CapabilityError, Dictionary, QueryPlan


def main():
    # LSM with ~1M-element capacity. batch_size is the paper's b; updates of
    # ANY length are accepted (padded / split into b-sized encoded batches).
    d = Dictionary.create("lsm", batch_size=1024, capacity=1 << 20)
    print(d)

    # 1) inserts — any length, not just multiples of b
    keys = jnp.arange(5000)
    d = d.insert(keys, keys * 10)
    print(f"inserted 5000; live size={int(d.size())} "
          f"(resident batches r={int(d.state.r)}, bits: {int(d.state.r):b})")

    # 2) point lookups — most-recent value wins
    found, vals = d.lookup(jnp.array([0, 1500, 4095, 99999]))
    print("lookup [0, 1500, 4095, 99999]:", found.tolist(), vals.tolist())

    # 3) overwrite: re-insert keys 0..1023 with a new value
    d = d.insert(jnp.arange(1024), jnp.full((1024,), 777))
    _, vals = d.lookup(jnp.array([0]))
    print("after overwrite, key 0 ->", int(vals[0]))

    # 4) delete (tombstones)
    d = d.delete(jnp.arange(1024) + 1024)
    found, _ = d.lookup(jnp.array([1500]))
    print("key 1500 after delete:", bool(found[0]))

    # 5) ordered queries (hash tables can't do this). QueryPlan auto-sizes
    #    the candidate tile; pass an explicit plan to override.
    counts, ok = d.count(jnp.array([0, 2048]), jnp.array([4999, 3000]))
    print(f"COUNT[0,4999]={int(counts[0])}  COUNT[2048,3000]={int(counts[1])} "
          f"(exact={bool(ok.all())})")
    rkeys, rvals, cnt, ok = d.range(2040, 2050, QueryPlan(max_results=16))
    print("RANGE[2040,2050] ->", rkeys[0][: int(cnt[0])].tolist())

    # 6) cleanup: purge tombstones + duplicates, shrink levels
    before = int(d.size())
    d = d.cleanup()
    print(f"cleanup: {before} live elements packed into r={int(d.state.r)} batches")

    # 7) same API, different backend: the sorted-array baseline. The auto
    #    plan truncates this all-keys query (ok=False); an explicit QueryPlan
    #    restores exactness — no silent wrong answers.
    sa = Dictionary.create("sorted_array", capacity=1 << 13)
    sa = sa.insert(jnp.arange(5000), jnp.arange(5000) * 10)
    counts, ok = sa.count(0, 4999)
    print(f"sorted_array COUNT[0,4999]={int(counts[0])} (auto plan, exact={bool(ok[0])})")
    counts, ok = sa.count(0, 4999, QueryPlan(max_candidates=1 << 13))
    print(f"sorted_array COUNT[0,4999]={int(counts[0])} (explicit plan, exact={bool(ok[0])})")

    # 8) cuckoo: O(1) lookups, but capability flags reject ordered queries
    ck = Dictionary.create("cuckoo", capacity=4096)
    ck = ck.bulk_build(np.arange(4000), np.arange(4000) % 97)
    found, _ = ck.lookup(jnp.array([17, 4001]))
    print("cuckoo lookup [17, 4001]:", found.tolist())
    try:
        ck.count(0, 100)
    except CapabilityError as e:
        print("cuckoo COUNT ->", e)


if __name__ == "__main__":
    main()
