"""LSM-backed paged-KV serving demo: the paper's dictionary doing real work
inside a decode loop (assignment: the technique as a first-class feature).

A tiny LM serves a stream of requests. The KV pool is paged; the logical->
physical page index is the GPU-LSM dictionary behind the unified
`repro.api.Dictionary` facade (the page table threads it as a pytree):
  * prefill admits pages (batch insert),
  * decode allocates a page every PAGE_SIZE tokens,
  * finished sequences are evicted (tombstone batch),
  * COUNT/RANGE audit live pages per sequence (ordered queries — the thing a
    hash-table index cannot do),
  * periodic CLEANUP compacts the index after churn.

  PYTHONPATH=src python examples/dictionary_serving.py

Multi-device variant (`--sharded`): the same facade calls, but the page
index is the range-partitioned LSM spread over every visible device —
`Dictionary.create("lsm_sharded", ...)` is the only line that changes.
On CPU, widen the device pool first:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/dictionary_serving.py --sharded

Continuous-batching variant (`--server`): many tenants' op streams
multiplexed onto ONE shared dictionary by `repro.serve.DictionaryServer` —
mixed decode-trickle / prefill-burst / eviction-storm traffic coalesces into
per-kind device steps, with live write-buffer occupancy, flush-cost, and
coalescing stats reported as the trace drains:

  PYTHONPATH=src python examples/dictionary_serving.py --server
"""

import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model_zoo as zoo
from repro.serve.kvcache import (
    PageTableConfig,
    pt_allocate,
    pt_compact,
    pt_evict,
    pt_init,
    pt_lookup,
    pt_seq_page_count,
)

PAGE_SIZE = 8
BATCH = 4


def main():
    cfg = get_smoke_config("qwen2-7b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    pt_cfg = PageTableConfig(num_pages=256, update_batch=16, num_levels=8)
    table = pt_init(pt_cfg)
    rng = np.random.default_rng(0)

    decode = jax.jit(functools.partial(zoo.apply_decode, cfg))

    print(f"serving {cfg.name}: page_size={PAGE_SIZE} pool={pt_cfg.num_pages} pages")
    for wave in range(3):
        seq_ids = np.arange(BATCH) + wave * BATCH
        prompt_len = 16
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, prompt_len)), jnp.int32)

        # --- prefill: admit prompt pages into the LSM page index ------------
        n_pages = prompt_len // PAGE_SIZE
        seqs, pages = [], []
        for s in seq_ids:
            for p in range(n_pages):
                seqs.append(s)
                pages.append(p)
        b = pt_cfg.update_batch
        valid = jnp.asarray(np.arange(b) < len(seqs))
        table, slots = pt_allocate(
            pt_cfg, table,
            jnp.asarray(np.resize(np.array(seqs, np.int32), b)),
            jnp.asarray(np.resize(np.array(pages, np.int32), b)),
            valid,
        )
        logits_pre, caches = zoo.apply_prefill(
            cfg, params, {"tokens": prompt}, cache_pad_to=prompt_len + 32
        )

        # --- decode loop: new page every PAGE_SIZE tokens --------------------
        token = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)[:, None]
        cache_len = jnp.asarray(prompt_len, jnp.int32)
        for t in range(16):
            logits, caches = decode(params, token, caches, cache_len)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cache_len = cache_len + 1
            if (prompt_len + t + 1) % PAGE_SIZE == 0:
                page_idx = (prompt_len + t + 1) // PAGE_SIZE - 1
                valid = jnp.asarray(np.arange(b) < BATCH)
                table, _ = pt_allocate(
                    pt_cfg, table,
                    jnp.asarray(np.resize(seq_ids.astype(np.int32), b)),
                    jnp.full((b,), page_idx, jnp.int32),
                    valid,
                )
        counts, ok = pt_seq_page_count(pt_cfg, table, jnp.asarray(seq_ids, jnp.int32),
                                       max_candidates=256)
        f, s = pt_lookup(pt_cfg, table, jnp.asarray([seq_ids[0]]), jnp.asarray([0]))
        print(f"wave {wave}: live pages/seq={np.asarray(counts).tolist()} "
              f"(exact={bool(ok.all())}) seq{seq_ids[0]}/page0 -> slot {int(s[0])} "
              f"free={int(table.free_count)}")

        # --- retire the previous wave (tombstone its pages) ------------------
        if wave > 0:
            old = np.arange(BATCH) + (wave - 1) * BATCH
            seqs, pages = [], []
            for s_ in old:
                for p in range(4):
                    seqs.append(s_)
                    pages.append(p)
            valid = jnp.asarray(np.arange(b) < len(seqs))
            table = pt_evict(
                pt_cfg, table,
                jnp.asarray(np.resize(np.array(seqs, np.int32), b)),
                jnp.asarray(np.resize(np.array(pages, np.int32), b)),
                valid,
            )
            print(f"  evicted wave {wave-1}: free={int(table.free_count)} "
                  f"(LSM r={int(table.index.state.r)} batches incl. tombstones, "
                  f"{int(table.index.size())} live)")

    table = pt_compact(pt_cfg, table)
    print(f"after CLEANUP: LSM r={int(table.index.state.r)} (tombstones purged)")


def sharded_variant():
    """The page-index workload on the sharded backend: one Dictionary.create
    change, identical insert/lookup/count/evict/cleanup calls."""
    from repro.api import Dictionary, QueryPlan

    shards = len(jax.devices())
    d = Dictionary.create("lsm_sharded", batch_size=16, num_levels=8,
                          num_shards=shards)
    print(f"sharded page index: {shards} shard(s), "
          f"batch={d.batch_size}, capacity={d.capacity}")
    rng = np.random.default_rng(0)

    # admit three waves of pages, evict the middle one
    keys = [rng.choice(1 << 20, 16, replace=False).astype(np.int32) for _ in range(3)]
    for wave, k in enumerate(keys):
        d = d.insert(k, jnp.asarray(k % 997, jnp.int32))
        print(f"  wave {wave}: size={int(d.size())}")
    d = d.delete(keys[1])
    d = d.cleanup()
    found, _ = d.lookup(np.concatenate([keys[0], keys[1]]))
    counts, ok = d.count(np.asarray([0]), np.asarray([(1 << 20) - 1]),
                         QueryPlan(max_candidates=4096))
    print(f"  after evict+cleanup: size={int(d.size())} "
          f"wave0-hits={int(np.asarray(found)[:16].sum())}/16 "
          f"wave1-hits={int(np.asarray(found)[16:].sum())}/16 "
          f"count[0,2^20)={int(counts[0])} exact={bool(ok[0])}")


def server_variant():
    """Mixed-tenant traffic through the continuous-batching server: live
    occupancy / flush-cost / coalescing reporting while the trace drains."""
    from repro.serve import DictionaryServer, ServerConfig, make_trace
    from repro.serve.kvcache import ServerPageTable

    srv = DictionaryServer(ServerConfig(
        backend="lsm", batch_size=64, num_levels=10,
        flush_at_fraction=0.75, maintenance_budget=128))
    tenants, trace = make_trace(
        "mixed", num_tenants=6, key_space=512, events=48, seed=0)
    for t in tenants:
        srv.register_tenant(t, key_space=512)
    # The KV page table rides along as just another tenant of the same
    # shared dictionary.
    pt = ServerPageTable(srv, num_pages=64, num_seqs=8)
    pt.allocate([0, 0, 1], [0, 1, 0])

    print(f"server: {len(tenants)} traffic tenants + page table over one "
          f"'{srv.config.backend}' dictionary (b={srv.config.batch_size})")
    tickets, window = [], 12
    for i, op in enumerate(trace):
        if op.kind == "update":
            tickets.append(srv.submit_update(op.tenant, op.keys, op.values,
                                             op.is_delete))
        elif op.kind == "lookup":
            tickets.append(srv.submit_lookup(op.tenant, op.keys))
        elif op.kind == "count":
            tickets.append(srv.submit_count(op.tenant, op.k1, op.k2))
        else:
            tickets.append(srv.submit_range(op.tenant, op.k1, op.k2,
                                            op.max_results))
        if (i + 1) % window == 0 or i == len(trace) - 1:
            srv.step()
            occ = srv.occupancy()
            print(f"  after {i + 1:3d} ops: pending={srv.pending_estimate()} "
                  f"(device: {int(occ.pending)}) resident={int(occ.resident)} "
                  f"debt={int(occ.debt)} "
                  f"flush_cost={int(srv.dictionary.flush_cost_estimate())} "
                  f"flushes={srv.stats.flushes}")
    stats = srv.drain()
    n_found = sum(
        int(np.asarray(t.result()[0]).sum())
        for t in tickets if t.kind == "lookup")
    counts, _ = pt.seq_page_count([0, 1]).result()
    print(f"drained: {stats.submitted} ops -> {stats.device_steps} device "
          f"steps ({stats.ops_per_device_step:.1f} ops/step), "
          f"flushes={stats.flushes} maintains={stats.maintains}")
    print(f"lookup hits across tenants: {n_found}; page table intact: "
          f"pages/seq={np.asarray(counts).tolist()} free={pt.free_count}")


if __name__ == "__main__":
    if "--sharded" in sys.argv:
        sharded_variant()
    elif "--server" in sys.argv:
        server_variant()
    else:
        main()
