"""End-to-end training driver example (assignment deliverable b).

Trains a reduced-config LM for a few hundred steps on CPU with the full
production stack: sharded train step, LSM-dedup data pipeline, async atomic
checkpointing, fault-injection + restart, straggler monitoring.

  PYTHONPATH=src python examples/train_lm.py                 # 200 steps, tiny
  PYTHONPATH=src python examples/train_lm.py --arch olmoe-1b-7b --steps 100
  PYTHONPATH=src python examples/train_lm.py --fail-at 60    # FT demo

On TPU hardware drop --smoke to train the full config over the discovered
mesh (the driver best-fits data x model axes to the device count).
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" not in argv and not any(a.startswith("--no-smoke") for a in argv):
        argv = ["--smoke"] + argv
    argv = [a for a in argv if not a.startswith("--no-smoke")]
    losses = main(argv)
    assert losses and losses[-1] < losses[0], "loss did not decrease"
