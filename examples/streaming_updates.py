"""Streaming dynamic-dictionary workload (paper §5 protocol, end to end).

A moving-objects index (the paper's motivating use case): objects stream
position updates (insert = overwrite), expire (delete), and a dashboard runs
COUNT/RANGE window queries — all through the unified `Dictionary` facade.
Garbage collection is two-tier: every update piggybacks a *budgeted*
incremental compaction (`maintenance_budget=` -> `maintain`, DESIGN.md §11)
that runs only when the cheap levels have tracked compaction debt, and a
stop-the-world `cleanup()` remains as the fallback policy for when stale
elements still exceed a threshold (deep-level garbage the budget can't
reach).

  PYTHONPATH=src python examples/streaming_updates.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Dictionary, QueryPlan

B = 4096
GRID = 1 << 20          # 1M cell ids (e.g. quantized 2D positions)


def main():
    # maintenance_budget: every update piggybacks maintain(3B) behind a
    # traced debt check — levels 0..1 (capacity 3B) stay compacted without
    # ever paying a stop-the-world cleanup on the update path.
    d = Dictionary.create("lsm", batch_size=B, num_levels=8,
                          maintenance_budget=3 * B)
    plan = QueryPlan(max_candidates=1 << 14)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    n_updates = 0
    for step in range(24):
        # 75% position updates, 25% expirations, in one mixed RAGGED batch —
        # objects report at their own cadence, so sizes are rarely b-aligned;
        # the facade's write buffer coalesces the trickle (no batch slot is
        # consumed until b elements are pending).
        n = int(rng.integers(B // 2, B + B // 2))
        keys = rng.integers(0, GRID, n).astype(np.int32)
        vals = rng.integers(0, 1 << 20, n).astype(np.int32)
        dels = rng.random(n) < 0.25
        d = d.update(jnp.asarray(keys), jnp.asarray(vals), is_delete=jnp.asarray(dels))
        n_updates += n

        if step % 6 == 5:
            # dashboard: occupancy of 4 map windows
            k1 = jnp.asarray([0, GRID // 4, GRID // 2, 3 * GRID // 4], jnp.int32)
            k2 = k1 + GRID // 4 - 1
            counts, ok = d.count(k1, k2, plan)
            staged = int(d.pending())
            resident = int(d.state.r) * B + staged
            live = int(d.size())
            stale_frac = 1 - live / max(resident, 1)
            debt = np.asarray(d.state.lvl_debt).tolist()
            print(f"step {step:2d}: windows={np.asarray(counts).tolist()} "
                  f"resident={resident} (staged={staged}) "
                  f"live={live} stale={stale_frac:.0%} debt={debt}")
            # incremental tier: one bounded maintain sweep of the deepest
            # affordable prefix (levels 0..2 at 7B) — latency O(budget), not
            # O(capacity), so it is safe to run on every dashboard tick.
            d = d.maintain(7 * B)
            # fallback tier: full cleanup only when deep-level garbage the
            # budget can't reach still dominates (>40% stale)
            live = int(d.size())
            resident = int(d.state.r) * B + int(d.pending())
            if 1 - live / max(resident, 1) > 0.4:
                d = d.cleanup()
                print(f"         cleanup -> r={int(d.state.r)} "
                      f"({int(d.state.r) * B} resident)")

    dt = time.perf_counter() - t0
    print(f"\n{n_updates} streamed updates in {dt:.1f}s "
          f"({n_updates / dt / 1e6:.2f} M updates/s on CPU; "
          f"K40c paper rate: 225 M/s)")
    keys, vals, cnt, ok = d.range(1000, 2000, QueryPlan(max_candidates=1 << 12, max_results=64))
    print(f"RANGE[1000,2000]: {int(cnt[0])} objects, first few keys "
          f"{keys[0][:min(5, int(cnt[0]))].tolist()}")


if __name__ == "__main__":
    main()
